//! Training configuration: typed structs + TOML-subset loading + validation.
//!
//! A config describes one LAD / Com-LAD run: system size (N, H), coding
//! load d, aggregation rule, attack, compression, workload and schedule.

pub mod toml;

use crate::Result;
use anyhow::{bail, Context};
use std::path::Path;
use toml::TomlValue;

/// Which robust aggregation rule the server applies (§II-A / Def. 1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AggregatorKind {
    Mean,
    Cwtm,
    Median,
    GeometricMedian,
    Krum,
    MultiKrum,
    Mcc,
    Faba,
    Tgn,
    /// Server-side momentum filtering (arXiv 2409.08640): per-device
    /// momentum buffers folded into a distance-filtered aggregate.
    MomentumFilter,
}

impl AggregatorKind {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "mean" | "avg" | "va" => AggregatorKind::Mean,
            "cwtm" | "trimmed-mean" => AggregatorKind::Cwtm,
            "median" | "cwmed" => AggregatorKind::Median,
            "geomed" | "geometric-median" => AggregatorKind::GeometricMedian,
            "krum" => AggregatorKind::Krum,
            "multi-krum" | "multikrum" => AggregatorKind::MultiKrum,
            "mcc" | "correntropy" => AggregatorKind::Mcc,
            "faba" => AggregatorKind::Faba,
            "tgn" | "norm-threshold" => AggregatorKind::Tgn,
            "momentum-filter" | "momfilter" | "cmf" => AggregatorKind::MomentumFilter,
            other => bail!("unknown aggregator {other:?}"),
        })
    }
    pub fn name(&self) -> &'static str {
        match self {
            AggregatorKind::Mean => "mean",
            AggregatorKind::Cwtm => "cwtm",
            AggregatorKind::Median => "median",
            AggregatorKind::GeometricMedian => "geomed",
            AggregatorKind::Krum => "krum",
            AggregatorKind::MultiKrum => "multi-krum",
            AggregatorKind::Mcc => "mcc",
            AggregatorKind::Faba => "faba",
            AggregatorKind::Tgn => "tgn",
            AggregatorKind::MomentumFilter => "momentum-filter",
        }
    }
}

/// Byzantine behaviour (§VII uses sign-flip with coefficient −2).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum AttackKind {
    None,
    SignFlip { coeff: f32 },
    Gaussian { std: f32 },
    Zero,
    Alie,
    Ipm { eps: f32 },
    Mimic,
    RandomSpike { scale: f32 },
}

impl AttackKind {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "none" | "honest" => AttackKind::None,
            "sign-flip" | "signflip" => AttackKind::SignFlip { coeff: -2.0 },
            "gaussian" => AttackKind::Gaussian { std: 10.0 },
            "zero" => AttackKind::Zero,
            "alie" => AttackKind::Alie,
            "ipm" => AttackKind::Ipm { eps: 0.5 },
            "mimic" => AttackKind::Mimic,
            "spike" | "random-spike" => AttackKind::RandomSpike { scale: 100.0 },
            other => bail!("unknown attack {other:?}"),
        })
    }
    pub fn name(&self) -> &'static str {
        match self {
            AttackKind::None => "none",
            AttackKind::SignFlip { .. } => "sign-flip",
            AttackKind::Gaussian { .. } => "gaussian",
            AttackKind::Zero => "zero",
            AttackKind::Alie => "alie",
            AttackKind::Ipm { .. } => "ipm",
            AttackKind::Mimic => "mimic",
            AttackKind::RandomSpike { .. } => "spike",
        }
    }
}

/// Compression operator (Def. 2; Com-LAD uses unbiased rand-K).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum CompressionKind {
    None,
    /// Unbiased random sparsification keeping `k` coordinates.
    RandK { k: usize },
    /// Biased top-K (ablation only; violates eq. (9)).
    TopK { k: usize },
    /// QSGD-style stochastic quantization with `levels` levels.
    Qsgd { levels: u32 },
    /// Error-feedback rand-K (arXiv 2310.09804): per-device residual
    /// memory wrapped around rand-K — `residual + gradient` is compressed
    /// and the compression error is carried to the next iteration.
    EfRandK { k: usize },
    /// Error-feedback top-K: EF memory turns the biased sparsifier into a
    /// contractive scheme (the Rammal et al. setting).
    EfTopK { k: usize },
    /// Error-feedback QSGD.
    EfQsgd { levels: u32 },
}

impl CompressionKind {
    pub fn name(&self) -> &'static str {
        match self {
            CompressionKind::None => "none",
            CompressionKind::RandK { .. } => "rand-k",
            CompressionKind::TopK { .. } => "top-k",
            CompressionKind::Qsgd { .. } => "qsgd",
            CompressionKind::EfRandK { .. } => "ef-rand-k",
            CompressionKind::EfTopK { .. } => "ef-top-k",
            CompressionKind::EfQsgd { .. } => "ef-qsgd",
        }
    }

    /// For an error-feedback kind, the underlying stateless operator the
    /// EF memory stage wraps; `None` for the plain (memoryless) kinds.
    pub fn ef_base(&self) -> Option<CompressionKind> {
        match *self {
            CompressionKind::EfRandK { k } => Some(CompressionKind::RandK { k }),
            CompressionKind::EfTopK { k } => Some(CompressionKind::TopK { k }),
            CompressionKind::EfQsgd { levels } => Some(CompressionKind::Qsgd { levels }),
            _ => None,
        }
    }

    /// Whether this kind carries per-device error-feedback state.
    pub fn is_ef(&self) -> bool {
        self.ef_base().is_some()
    }
}

/// How gradients are produced.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OracleKind {
    /// Native Rust linear-regression gradients (fast path / no artifacts).
    NativeLinreg,
    /// PJRT-executed AOT artifact (JAX + Pallas `coded_grad` kernel).
    RuntimeLinreg,
}

/// Multi-node transport settings (the `[net]` table; consumed by
/// `lad node-leader` / `lad node-worker`). Execution-local: excluded from
/// the handshake config digest, so leader and workers may differ here.
#[derive(Debug, Clone, PartialEq)]
pub struct NetConfig {
    /// Leader listen / worker connect address: `tcp://host:port` (or a
    /// bare `host:port`), or `uds:/path/to.sock` for a Unix-domain socket.
    pub addr: String,
    /// Per-iteration gather deadline in milliseconds; 0 waits forever. A
    /// positive deadline lets the leader proceed past stalled
    /// (crash-Byzantine) workers, counting them as trace anomalies.
    pub gather_deadline_ms: u64,
    /// Join-handshake deadline in milliseconds; 0 waits forever. With a
    /// positive deadline, an accepted connection that never sends a valid
    /// `Join` is dropped after this long and its device slot reclaimed
    /// (`net::Leader::serve`) instead of occupying one of the N slots
    /// forever.
    pub join_deadline_ms: u64,
    /// Compression site: `true` = honest devices compress their own
    /// uplink (Com-LAD device-side, compressed bytes on the wire);
    /// `false` = devices ship dense vectors and the leader compresses
    /// (the historical simulation mode; required for omniscient attacks).
    pub device_compression: bool,
}

impl Default for NetConfig {
    fn default() -> Self {
        NetConfig {
            addr: "tcp://127.0.0.1:7700".into(),
            gather_deadline_ms: 0,
            join_deadline_ms: 0,
            device_compression: false,
        }
    }
}

/// Top-level run configuration (defaults reproduce Fig. 4's LAD-CWTM d=10).
#[derive(Debug, Clone)]
pub struct TrainConfig {
    /// Total devices N.
    pub n_devices: usize,
    /// Honest devices H (N−H are Byzantine). Must satisfy H > N/2.
    pub n_honest: usize,
    /// Computational load d: subsets per device per iteration (1 ⇒ no coding).
    pub d: usize,
    /// Model dimension Q.
    pub dim: usize,
    /// Iterations T.
    pub iters: usize,
    /// Fixed learning rate γ.
    pub lr: f64,
    /// Data heterogeneity σ_H (§VII).
    pub sigma_h: f64,
    /// Aggregation rule.
    pub aggregator: AggregatorKind,
    /// Apply NNM pre-aggregation before the rule (CWTM-NNM etc).
    pub nnm: bool,
    /// CWTM trim fraction (paper: 0.1) / TGN drop fraction (paper: 0.2).
    pub trim_frac: f64,
    /// Attack executed by Byzantine devices.
    pub attack: AttackKind,
    /// Compression operator (Com-LAD) applied device-side.
    pub compression: CompressionKind,
    /// Gradient oracle.
    pub oracle: OracleKind,
    /// RNG seed.
    pub seed: u64,
    /// Log every `log_every` iterations (0 = only final).
    pub log_every: usize,
    /// Worker threads for the device-parallel stages (gradient oracle,
    /// per-device compression, tiled pairwise-distance aggregation). `1` =
    /// serial (the default), `0` = all available cores. The trainer spins
    /// up one persistent `util::parallel::Pool` per run and shares it
    /// across all three stages, so no per-iteration spawn cost remains.
    /// Any value produces bit-identical traces: randomness is pre-split per
    /// device, never shared across threads (see `util::parallel`). Note:
    /// compression randomness always comes from per-device split streams,
    /// so runs with stochastic compressors (rand-K/QSGD) follow a
    /// different — but equally seeded-deterministic — trajectory than the
    /// pre-parallel trainer did; identity-compression runs are unchanged.
    pub threads: usize,
    /// Multi-node transport settings (`[net]` table).
    pub net: NetConfig,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            n_devices: 100,
            n_honest: 80,
            d: 10,
            dim: 100,
            iters: 500,
            lr: 1e-6,
            sigma_h: 0.3,
            aggregator: AggregatorKind::Cwtm,
            nnm: false,
            trim_frac: 0.1,
            attack: AttackKind::SignFlip { coeff: -2.0 },
            compression: CompressionKind::None,
            oracle: OracleKind::NativeLinreg,
            seed: 0xC0FFEE,
            log_every: 50,
            threads: 1,
            net: NetConfig::default(),
        }
    }
}

impl TrainConfig {
    /// Number of Byzantine devices N − H.
    pub fn n_byz(&self) -> usize {
        self.n_devices - self.n_honest
    }

    /// Validate the structural constraints from §III-B / §IV.
    pub fn validate(&self) -> Result<()> {
        if self.n_devices == 0 || self.dim == 0 || self.iters == 0 {
            bail!("n_devices, dim, iters must be positive");
        }
        if self.n_honest > self.n_devices {
            bail!("H={} > N={}", self.n_honest, self.n_devices);
        }
        if 2 * self.n_honest <= self.n_devices {
            bail!("need H > N/2 (got H={}, N={})", self.n_honest, self.n_devices);
        }
        if self.d == 0 || self.d > self.n_devices {
            bail!("need 1 <= d <= N (got d={}, N={})", self.d, self.n_devices);
        }
        if !(0.0..0.5).contains(&self.trim_frac) {
            bail!("trim_frac must be in [0, 0.5)");
        }
        if self.lr <= 0.0 {
            bail!("lr must be positive");
        }
        if let CompressionKind::RandK { k }
        | CompressionKind::TopK { k }
        | CompressionKind::EfRandK { k }
        | CompressionKind::EfTopK { k } = self.compression
        {
            if k == 0 || k > self.dim {
                bail!("compression k={} out of range 1..={}", k, self.dim);
            }
        }
        Ok(())
    }

    /// Load from a TOML-subset file; unspecified keys keep defaults.
    pub fn from_file<P: AsRef<Path>>(path: P) -> Result<Self> {
        let body = std::fs::read_to_string(&path)
            .with_context(|| format!("reading config {:?}", path.as_ref()))?;
        Self::from_toml_str(&body)
    }

    /// Parse from TOML text. Keys live at top level or under `[train]`.
    pub fn from_toml_str(body: &str) -> Result<Self> {
        let doc = toml::parse(body).map_err(|e| anyhow::anyhow!("config parse error: {e}"))?;
        let mut cfg = TrainConfig::default();
        for table in ["", "train"] {
            if let Some(kv) = doc.get(table) {
                apply_train_table(&mut cfg, kv)?;
            }
        }
        if let Some(kv) = doc.get("net") {
            apply_net_table(&mut cfg.net, kv)?;
        }
        cfg.validate()?;
        Ok(cfg)
    }
}

/// Apply one `key = value` table of `[net]` keys (shared with the sweep
/// spec's `[net]` section).
pub(crate) fn apply_net_table(
    net: &mut NetConfig,
    kv: &std::collections::BTreeMap<String, TomlValue>,
) -> Result<()> {
    // `addr`/`listen`/`connect` (and `gather_deadline_ms`/`deadline_ms`)
    // are aliases for one field; two of them in one file is a
    // contradiction (key order, not file order, would pick the winner),
    // so reject it instead of silently resolving
    let mut addr_key: Option<&str> = None;
    let mut deadline_key: Option<&str> = None;
    for (key, v) in kv {
        match key.as_str() {
            "addr" | "listen" | "connect" => {
                if let Some(prev) = addr_key {
                    bail!("[net] key {key:?} conflicts with {prev:?} — set only one address");
                }
                addr_key = Some(key.as_str());
                net.addr = v.as_str().context("net.addr must be a string")?.to_string()
            }
            "gather_deadline_ms" | "deadline_ms" => {
                if let Some(prev) = deadline_key {
                    bail!("[net] key {key:?} conflicts with {prev:?} — set only one deadline");
                }
                deadline_key = Some(key.as_str());
                net.gather_deadline_ms = need_usize(key, v)? as u64
            }
            "join_deadline_ms" => net.join_deadline_ms = need_usize(key, v)? as u64,
            "compression_site" => {
                net.device_compression =
                    match v.as_str().context("net.compression_site must be a string")? {
                        "device" => true,
                        "leader" => false,
                        other => bail!("net.compression_site must be leader|device, got {other:?}"),
                    }
            }
            other => bail!("unknown [net] key {other:?}"),
        }
    }
    Ok(())
}

/// Apply one `key = value` table of training keys onto a config (shared
/// between `[train]` / top-level config loading and the sweep spec's
/// `[fixed]` section). Relies on `BTreeMap` iteration order: `q_hat` sorts
/// after `compression`, so the sparsifier width lands on the operator the
/// same table selected.
pub(crate) fn apply_train_table(
    cfg: &mut TrainConfig,
    kv: &std::collections::BTreeMap<String, TomlValue>,
) -> Result<()> {
    for (key, v) in kv {
        match key.as_str() {
            "n_devices" | "devices" => cfg.n_devices = need_usize(key, v)?,
            "n_honest" | "honest" => cfg.n_honest = need_usize(key, v)?,
            "d" | "load" => cfg.d = need_usize(key, v)?,
            "dim" | "q" => cfg.dim = need_usize(key, v)?,
            "iters" | "iterations" => cfg.iters = need_usize(key, v)?,
            "lr" | "learning_rate" => cfg.lr = need_f64(key, v)?,
            "sigma_h" | "heterogeneity" => cfg.sigma_h = need_f64(key, v)?,
            "trim_frac" => cfg.trim_frac = need_f64(key, v)?,
            "seed" => cfg.seed = need_usize(key, v)? as u64,
            "log_every" => cfg.log_every = need_usize(key, v)?,
            "threads" => cfg.threads = need_usize(key, v)?,
            "nnm" => {
                cfg.nnm = v.as_bool().with_context(|| format!("{key} must be bool"))?
            }
            "aggregator" => {
                cfg.aggregator =
                    AggregatorKind::parse(v.as_str().context("aggregator must be string")?)?
            }
            "attack" => {
                cfg.attack = AttackKind::parse(v.as_str().context("attack must be string")?)?
            }
            "oracle" => {
                cfg.oracle = match v.as_str().context("oracle must be string")? {
                    "native" | "native-linreg" => OracleKind::NativeLinreg,
                    "runtime" | "runtime-linreg" | "pjrt" => OracleKind::RuntimeLinreg,
                    other => bail!("unknown oracle {other:?}"),
                }
            }
            "compression" => {
                cfg.compression = match v.as_str().context("compression must be string")? {
                    "none" => CompressionKind::None,
                    "rand-k" | "randk" => CompressionKind::RandK { k: 30 },
                    "top-k" | "topk" => CompressionKind::TopK { k: 30 },
                    "qsgd" => CompressionKind::Qsgd { levels: 16 },
                    "ef-rand-k" | "ef-randk" => CompressionKind::EfRandK { k: 30 },
                    "ef-top-k" | "ef-topk" => CompressionKind::EfTopK { k: 30 },
                    "ef-qsgd" => CompressionKind::EfQsgd { levels: 16 },
                    other => bail!("unknown compression {other:?}"),
                }
            }
            "compression_k" | "q_hat" => {
                let k = need_usize(key, v)?;
                cfg.compression = match cfg.compression {
                    CompressionKind::TopK { .. } => CompressionKind::TopK { k },
                    CompressionKind::EfTopK { .. } => CompressionKind::EfTopK { k },
                    CompressionKind::EfRandK { .. } => CompressionKind::EfRandK { k },
                    CompressionKind::Qsgd { .. } | CompressionKind::EfQsgd { .. } => {
                        bail!("q_hat does not apply to qsgd")
                    }
                    _ => CompressionKind::RandK { k },
                };
            }
            other => bail!("unknown config key {other:?}"),
        }
    }
    Ok(())
}

fn need_usize(key: &str, v: &TomlValue) -> Result<usize> {
    v.as_usize().with_context(|| format!("{key} must be a non-negative integer"))
}
fn need_f64(key: &str, v: &TomlValue) -> Result<f64> {
    v.as_f64().with_context(|| format!("{key} must be a number"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_valid() {
        TrainConfig::default().validate().unwrap();
    }

    #[test]
    fn parse_roundtrip() {
        let cfg = TrainConfig::from_toml_str(
            r#"
            [train]
            devices = 100
            honest = 70
            d = 3
            lr = 3e-7
            sigma_h = 0.3
            aggregator = "cwtm"
            nnm = true
            attack = "sign-flip"
            compression = "rand-k"
            q_hat = 30
            "#,
        )
        .unwrap();
        assert_eq!(cfg.n_honest, 70);
        assert_eq!(cfg.n_byz(), 30);
        assert_eq!(cfg.d, 3);
        assert!(cfg.nnm);
        assert_eq!(cfg.compression, CompressionKind::RandK { k: 30 });
    }

    #[test]
    fn threads_key_parses_and_defaults_serial() {
        assert_eq!(TrainConfig::default().threads, 1);
        let cfg = TrainConfig::from_toml_str("threads = 8").unwrap();
        assert_eq!(cfg.threads, 8);
        let auto = TrainConfig::from_toml_str("threads = 0").unwrap();
        assert_eq!(auto.threads, 0);
    }

    #[test]
    fn net_table_parses_and_defaults() {
        let cfg = TrainConfig::default();
        assert_eq!(cfg.net, NetConfig::default());
        assert_eq!(cfg.net.gather_deadline_ms, 0);
        assert!(!cfg.net.device_compression);
        let cfg = TrainConfig::from_toml_str(
            r#"
            devices = 10
            honest = 8
            [net]
            listen = "uds:/tmp/lad.sock"
            gather_deadline_ms = 250
            join_deadline_ms = 900
            compression_site = "device"
            "#,
        )
        .unwrap();
        assert_eq!(cfg.net.addr, "uds:/tmp/lad.sock");
        assert_eq!(cfg.net.gather_deadline_ms, 250);
        assert_eq!(cfg.net.join_deadline_ms, 900);
        assert!(cfg.net.device_compression);
        assert!(TrainConfig::from_toml_str("[net]\ncompression_site = \"nowhere\"").is_err());
        assert!(TrainConfig::from_toml_str("[net]\nbogus = 1").is_err());
        // contradictory aliases are rejected, not key-order-resolved
        let conflict = "[net]\nconnect = \"tcp://a:1\"\nlisten = \"uds:/tmp/x\"";
        assert!(TrainConfig::from_toml_str(conflict).is_err());
        let conflict = "[net]\ndeadline_ms = 5000\ngather_deadline_ms = 100";
        assert!(TrainConfig::from_toml_str(conflict).is_err());
    }

    #[test]
    fn rejects_minority_honest() {
        let r = TrainConfig::from_toml_str("devices = 10\nhonest = 5");
        assert!(r.is_err());
    }

    #[test]
    fn rejects_bad_d() {
        let mut cfg = TrainConfig::default();
        cfg.d = 101;
        assert!(cfg.validate().is_err());
        cfg.d = 0;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn rejects_unknown_key() {
        assert!(TrainConfig::from_toml_str("bogus_key = 3").is_err());
    }

    #[test]
    fn aggregator_names_roundtrip() {
        for k in [
            AggregatorKind::Mean,
            AggregatorKind::Cwtm,
            AggregatorKind::Median,
            AggregatorKind::GeometricMedian,
            AggregatorKind::Krum,
            AggregatorKind::MultiKrum,
            AggregatorKind::Mcc,
            AggregatorKind::Faba,
            AggregatorKind::Tgn,
            AggregatorKind::MomentumFilter,
        ] {
            assert_eq!(AggregatorKind::parse(k.name()).unwrap(), k);
        }
    }

    #[test]
    fn ef_kinds_parse_validate_and_unwrap() {
        let cfg = TrainConfig::from_toml_str(
            r#"
            devices = 100
            honest = 80
            compression = "ef-rand-k"
            q_hat = 12
            "#,
        )
        .unwrap();
        assert_eq!(cfg.compression, CompressionKind::EfRandK { k: 12 });
        assert_eq!(cfg.compression.ef_base(), Some(CompressionKind::RandK { k: 12 }));
        assert!(cfg.compression.is_ef());
        assert!(!CompressionKind::Qsgd { levels: 4 }.is_ef());
        let cfg = TrainConfig::from_toml_str("compression = \"ef-qsgd\"").unwrap();
        assert_eq!(cfg.compression, CompressionKind::EfQsgd { levels: 16 });
        // q_hat does not retarget a quantizer, EF or not
        assert!(TrainConfig::from_toml_str("compression = \"ef-qsgd\"\nq_hat = 5").is_err());
        // k range checks cover the EF sparsifiers
        let mut bad = TrainConfig::default();
        bad.compression = CompressionKind::EfTopK { k: bad.dim + 1 };
        assert!(bad.validate().is_err());
    }
}
