//! TOML-subset parser (offline sandbox: no `toml`/`serde` crates).
//!
//! Supported grammar — enough for training configs:
//! `[table]` headers, `key = value` with value ∈ {integer, float, bool,
//! "string", [array of scalars]}, `#` comments, blank lines.

use std::collections::BTreeMap;

/// A parsed scalar or array value.
#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    Int(i64),
    Float(f64),
    Bool(bool),
    Str(String),
    Arr(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            TomlValue::Int(x) => Some(*x),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Float(x) => Some(*x),
            TomlValue::Int(x) => Some(*x as f64),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().and_then(|x| usize::try_from(x).ok())
    }
}

/// Parsed document: table name → (key → value). Top-level keys live under "".
pub type TomlDoc = BTreeMap<String, BTreeMap<String, TomlValue>>;

/// Parse a TOML-subset document.
pub fn parse(input: &str) -> Result<TomlDoc, String> {
    let mut doc: TomlDoc = BTreeMap::new();
    let mut table = String::new();
    doc.insert(String::new(), BTreeMap::new());
    for (lineno, raw) in input.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(name) = line.strip_prefix('[').and_then(|l| l.strip_suffix(']')) {
            table = name.trim().to_string();
            if table.is_empty() {
                return Err(format!("line {}: empty table name", lineno + 1));
            }
            doc.entry(table.clone()).or_default();
            continue;
        }
        let (key, val) = line
            .split_once('=')
            .ok_or_else(|| format!("line {}: expected key = value", lineno + 1))?;
        let key = key.trim();
        if key.is_empty() {
            return Err(format!("line {}: empty key", lineno + 1));
        }
        let value = parse_value(val.trim())
            .map_err(|e| format!("line {}: {}", lineno + 1, e))?;
        doc.get_mut(&table).unwrap().insert(key.to_string(), value);
    }
    Ok(doc)
}

fn strip_comment(line: &str) -> &str {
    // respect '#' inside quoted strings
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<TomlValue, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if s == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if s == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if let Some(inner) = s.strip_prefix('"') {
        let inner = inner.strip_suffix('"').ok_or("unterminated string")?;
        return Ok(TomlValue::Str(unescape(inner)));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner.strip_suffix(']').ok_or("unterminated array")?.trim();
        if inner.is_empty() {
            return Ok(TomlValue::Arr(vec![]));
        }
        let mut items = Vec::new();
        for part in split_array_items(inner) {
            items.push(parse_value(part.trim())?);
        }
        return Ok(TomlValue::Arr(items));
    }
    // number: int if it parses as i64 and has no float markers
    if !s.contains(['.', 'e', 'E']) {
        if let Ok(i) = s.replace('_', "").parse::<i64>() {
            return Ok(TomlValue::Int(i));
        }
    }
    s.replace('_', "")
        .parse::<f64>()
        .map(TomlValue::Float)
        .map_err(|_| format!("cannot parse value: {s:?}"))
}

fn split_array_items(s: &str) -> Vec<&str> {
    // split on commas outside quotes (nested arrays unsupported)
    let mut parts = Vec::new();
    let mut start = 0;
    let mut in_str = false;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            ',' if !in_str => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    parts.push(&s[start..]);
    parts
}

fn unescape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    let mut chars = s.chars();
    while let Some(c) = chars.next() {
        if c == '\\' {
            match chars.next() {
                Some('n') => out.push('\n'),
                Some('t') => out.push('\t'),
                Some('"') => out.push('"'),
                Some('\\') => out.push('\\'),
                Some(other) => {
                    out.push('\\');
                    out.push(other);
                }
                None => out.push('\\'),
            }
        } else {
            out.push(c);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_full_config() {
        let doc = parse(
            r#"
            # training config
            seed = 42
            [train]
            devices = 100
            lr = 1e-6          # learning rate
            sigma_h = 0.3
            aggregator = "cwtm-nnm"
            use_nnm = true
            d_values = [5, 10, 20]
            "#,
        )
        .unwrap();
        assert_eq!(doc[""]["seed"], TomlValue::Int(42));
        assert_eq!(doc["train"]["devices"].as_usize(), Some(100));
        assert_eq!(doc["train"]["lr"].as_f64(), Some(1e-6));
        assert_eq!(doc["train"]["aggregator"].as_str(), Some("cwtm-nnm"));
        assert_eq!(doc["train"]["use_nnm"].as_bool(), Some(true));
        let arr = match &doc["train"]["d_values"] {
            TomlValue::Arr(a) => a,
            _ => panic!(),
        };
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].as_i64(), Some(20));
    }

    #[test]
    fn hash_inside_string_is_kept() {
        let doc = parse(r#"name = "a#b" # trailing"#).unwrap();
        assert_eq!(doc[""]["name"].as_str(), Some("a#b"));
    }

    #[test]
    fn rejects_malformed() {
        assert!(parse("[unclosed").is_err() || parse("[unclosed").unwrap().is_empty() == false);
        assert!(parse("novalue").is_err());
        assert!(parse("k = ").is_err());
        assert!(parse("k = \"unterminated").is_err());
    }

    #[test]
    fn underscores_in_numbers() {
        let doc = parse("n = 1_000_000\nx = 1_0.5").unwrap();
        assert_eq!(doc[""]["n"].as_i64(), Some(1_000_000));
        assert_eq!(doc[""]["x"].as_f64(), Some(10.5));
    }

    #[test]
    fn string_escapes() {
        let doc = parse(r#"s = "a\nb\"c""#).unwrap();
        assert_eq!(doc[""]["s"].as_str(), Some("a\nb\"c"));
    }
}
