//! Synthetic byte-level corpus for the end-to-end transformer driver.
//!
//! Generates "text" from per-shard Markov chains over a small byte alphabet
//! so that (a) the LM has real sequential structure to learn and (b) shards
//! are *heterogeneous* (each shard's chain is biased differently), matching
//! the paper's non-IID setting.

use crate::util::rng::Rng;

/// A sharded token corpus (tokens are bytes < `vocab`).
#[derive(Debug, Clone)]
pub struct Corpus {
    pub vocab: usize,
    /// tokens[s] = token stream of shard s
    pub shards: Vec<Vec<i32>>,
}

impl Corpus {
    /// Generate `n_shards` streams of `len` tokens each. `heterogeneity`
    /// in [0, 1] interpolates each shard's transition bias away from a
    /// shared base chain.
    pub fn generate(
        n_shards: usize,
        len: usize,
        vocab: usize,
        heterogeneity: f64,
        rng: &mut Rng,
    ) -> Self {
        assert!(vocab >= 4);
        // shared base chain: each token prefers (t + 1) mod vocab (a cycle),
        // giving the LM an easily learnable structure
        let mut shards = Vec::with_capacity(n_shards);
        for s in 0..n_shards {
            // shard-specific preferred offset drifts with heterogeneity
            let offset = 1 + ((s as f64 * heterogeneity * 3.0) as usize) % (vocab - 1);
            let mut stream = Vec::with_capacity(len);
            let mut t = rng.below(vocab);
            for _ in 0..len {
                stream.push(t as i32);
                t = if rng.bernoulli(0.8) {
                    (t + offset) % vocab
                } else {
                    rng.below(vocab)
                };
            }
            shards.push(stream);
        }
        Corpus { vocab, shards }
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// Sample a batch of (seq_len+1)-token windows from one shard; layout is
    /// row-major [batch, seq_len+1] ready for the transformer artifact
    /// (inputs = window[..-1], targets = window[1..]).
    pub fn sample_batch(
        &self,
        shard: usize,
        batch: usize,
        seq_len: usize,
        rng: &mut Rng,
    ) -> Vec<i32> {
        let stream = &self.shards[shard];
        let window = seq_len + 1;
        assert!(stream.len() > window, "shard too short");
        let mut out = Vec::with_capacity(batch * window);
        for _ in 0..batch {
            let start = rng.below(stream.len() - window);
            out.extend_from_slice(&stream[start..start + window]);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tokens_in_vocab() {
        let mut rng = Rng::new(1);
        let c = Corpus::generate(4, 500, 16, 0.5, &mut rng);
        for s in &c.shards {
            assert_eq!(s.len(), 500);
            assert!(s.iter().all(|&t| t >= 0 && (t as usize) < 16));
        }
    }

    #[test]
    fn batch_layout() {
        let mut rng = Rng::new(2);
        let c = Corpus::generate(2, 300, 8, 0.0, &mut rng);
        let b = c.sample_batch(1, 3, 10, &mut rng);
        assert_eq!(b.len(), 3 * 11);
        assert!(b.iter().all(|&t| (t as usize) < 8));
    }

    #[test]
    fn structure_is_learnable() {
        // the base chain prefers t -> t+offset; verify transition skew
        let mut rng = Rng::new(3);
        let c = Corpus::generate(1, 20_000, 8, 0.0, &mut rng);
        let s = &c.shards[0];
        let mut follow = 0usize;
        for w in s.windows(2) {
            if w[1] == (w[0] + 1) % 8 {
                follow += 1;
            }
        }
        let frac = follow as f64 / (s.len() - 1) as f64;
        assert!(frac > 0.6, "follow fraction {frac}"); // 0.8 + 0.2/8 ≈ 0.825
    }

    #[test]
    fn shards_are_heterogeneous() {
        let mut rng = Rng::new(4);
        let c = Corpus::generate(3, 5_000, 8, 1.0, &mut rng);
        // shard 0 and shard 2 should have different dominant offsets
        let dominant = |s: &[i32]| -> usize {
            let mut cnt = vec![0usize; 8];
            for w in s.windows(2) {
                cnt[((w[1] - w[0]).rem_euclid(8)) as usize] += 1;
            }
            (0..8).max_by_key(|&o| cnt[o]).unwrap()
        };
        assert_ne!(dominant(&c.shards[0]), dominant(&c.shards[2]));
    }
}
