//! Workload generators: the paper's heterogeneous linear-regression task
//! (§VII) and a synthetic byte-level corpus for the end-to-end transformer
//! driver.

pub mod corpus;
pub mod linreg;

pub use corpus::Corpus;
pub use linreg::LinRegDataset;
