//! The paper's §VII workload: heterogeneous linear regression.
//!
//! N subsets, one sample each: features z_k ∈ R^Q with entries ~ N(0, 100);
//! a per-subset ground-truth x̂_k with entries ~ N(0, 1 + k·σ_H) (so larger
//! σ_H ⇒ more heterogeneity across subsets; σ_H = 0 ⇒ IID); labels
//! y_k ~ N(⟨z_k, x̂_k⟩, 1). Loss f_k(x) = ½(⟨x, z_k⟩ − y_k)²,
//! ∇f_k(x) = (⟨x, z_k⟩ − y_k)·z_k, F = Σ_k f_k.

use crate::util::math::{dot, Mat};
use crate::util::parallel::{Parallelism, Pool};
use crate::util::rng::Rng;

/// Generated regression workload.
#[derive(Debug, Clone)]
pub struct LinRegDataset {
    /// features, N×Q (row k = z_k)
    pub z: Mat,
    /// labels
    pub y: Vec<f32>,
    /// heterogeneity parameter used at generation (for logging)
    pub sigma_h: f64,
}

impl LinRegDataset {
    /// Generate per §VII with feature std 10 (= N(0, 100)).
    pub fn generate(n: usize, q: usize, sigma_h: f64, rng: &mut Rng) -> Self {
        let mut z = Mat::zeros(n, q);
        let mut y = vec![0.0f32; n];
        for k in 0..n {
            let row = z.row_mut(k);
            for v in row.iter_mut() {
                *v = rng.normal(0.0, 10.0) as f32;
            }
            // per-subset ground truth with variance 1 + k·σ_H
            let std = (1.0 + k as f64 * sigma_h).sqrt();
            let xhat: Vec<f32> = (0..q).map(|_| rng.normal(0.0, std) as f32).collect();
            let mean = dot(z.row(k), &xhat) as f64;
            y[k] = rng.normal(mean, 1.0) as f32;
        }
        LinRegDataset { z, y, sigma_h }
    }

    pub fn n(&self) -> usize {
        self.z.rows
    }
    pub fn dim(&self) -> usize {
        self.z.cols
    }

    /// residual r_k = ⟨x, z_k⟩ − y_k.
    pub fn residuals(&self, x: &[f32], out: &mut [f32]) {
        assert_eq!(out.len(), self.n());
        self.z.matvec(x, out);
        for (r, &yk) in out.iter_mut().zip(&self.y) {
            *r -= yk;
        }
    }

    /// Row-parallel [`Self::residuals`]; bit-identical for any thread count
    /// (each residual is an independent dot product).
    pub fn residuals_par(&self, x: &[f32], out: &mut [f32], par: Parallelism) {
        self.residuals_pool(x, out, &Pool::scoped(par));
    }

    /// [`Self::residuals_par`] on a shared worker pool.
    pub fn residuals_pool(&self, x: &[f32], out: &mut [f32], pool: &Pool) {
        assert_eq!(out.len(), self.n());
        pool.par_chunks_mut(out, 1, |k, r| {
            r[0] = dot(self.z.row(k), x) - self.y[k];
        });
    }

    /// F(x) = Σ_k ½ r_k².
    pub fn loss(&self, x: &[f32]) -> f64 {
        let mut r = vec![0.0f32; self.n()];
        self.residuals(x, &mut r);
        r.iter().map(|&v| 0.5 * (v as f64) * (v as f64)).sum()
    }

    /// ∇f_k(x) for a single subset.
    pub fn subset_grad(&self, k: usize, x: &[f32]) -> Vec<f32> {
        let r = dot(self.z.row(k), x) - self.y[k];
        self.z.row(k).iter().map(|&z| r * z).collect()
    }

    /// Per-subset gradient matrix G (row k = ∇f_k(x)) — the quantity the
    /// `coded_grad` Pallas kernel computes on the AOT path.
    pub fn grad_matrix(&self, x: &[f32], out: &mut Mat) {
        self.grad_matrix_par(x, out, Parallelism::serial());
    }

    /// Row-parallel [`Self::grad_matrix`]: residuals and the rank-1 row
    /// fills are independent per subset, so rows distribute across threads
    /// with bit-identical output for any thread count.
    pub fn grad_matrix_par(&self, x: &[f32], out: &mut Mat, par: Parallelism) {
        self.grad_matrix_pool(x, out, &Pool::scoped(par));
    }

    /// [`Self::grad_matrix_par`] on a shared worker pool.
    pub fn grad_matrix_pool(&self, x: &[f32], out: &mut Mat, pool: &Pool) {
        assert_eq!(out.rows, self.n());
        assert_eq!(out.cols, self.dim());
        let mut r = vec![0.0f32; self.n()];
        self.residuals_pool(x, &mut r, pool);
        let cols = self.dim();
        pool.par_chunks_mut(&mut out.data, cols, |k, dst| {
            let src = self.z.row(k);
            let rk = r[k];
            for (d, &s) in dst.iter_mut().zip(src) {
                *d = rk * s;
            }
        });
    }

    /// ∇F(x) = Σ_k ∇f_k(x).
    pub fn full_grad(&self, x: &[f32]) -> Vec<f32> {
        let mut r = vec![0.0f32; self.n()];
        self.residuals(x, &mut r);
        let mut g = vec![0.0f32; self.dim()];
        for k in 0..self.n() {
            crate::util::math::axpy(r[k], self.z.row(k), &mut g);
        }
        g
    }

    /// Empirical heterogeneity: (1/N) Σ‖∇f_k(x) − μ‖² at a point x
    /// (the β² of Assumption 2 along the trajectory).
    pub fn heterogeneity_at(&self, x: &[f32]) -> f64 {
        let mut g = Mat::zeros(self.n(), self.dim());
        self.grad_matrix(x, &mut g);
        let mu: Vec<f32> = (0..self.dim())
            .map(|j| (0..self.n()).map(|k| g.row(k)[j]).sum::<f32>() / self.n() as f32)
            .collect();
        (0..self.n())
            .map(|k| crate::util::math::dist_sq(g.row(k), &mu))
            .sum::<f64>()
            / self.n() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> (LinRegDataset, Vec<f32>) {
        let mut rng = Rng::new(1);
        let ds = LinRegDataset::generate(10, 6, 0.3, &mut rng);
        let x = rng.gauss_vec(6);
        (ds, x)
    }

    #[test]
    fn shapes() {
        let (ds, _) = small();
        assert_eq!(ds.n(), 10);
        assert_eq!(ds.dim(), 6);
        assert_eq!(ds.y.len(), 10);
    }

    #[test]
    fn grad_matrix_matches_subset_grads() {
        let (ds, x) = small();
        let mut g = Mat::zeros(10, 6);
        ds.grad_matrix(&x, &mut g);
        for k in 0..10 {
            let want = ds.subset_grad(k, &x);
            for j in 0..6 {
                assert!((g.row(k)[j] - want[j]).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn full_grad_is_sum_of_rows() {
        let (ds, x) = small();
        let mut g = Mat::zeros(10, 6);
        ds.grad_matrix(&x, &mut g);
        let full = ds.full_grad(&x);
        for j in 0..6 {
            let s: f32 = (0..10).map(|k| g.row(k)[j]).sum();
            assert!((full[j] - s).abs() < 1e-3);
        }
    }

    #[test]
    fn gradient_is_numerically_correct() {
        let (ds, x) = small();
        let g = ds.full_grad(&x);
        let eps = 1e-3f32;
        for j in [0usize, 3, 5] {
            let mut xp = x.clone();
            xp[j] += eps;
            let mut xm = x.clone();
            xm[j] -= eps;
            let fd = (ds.loss(&xp) - ds.loss(&xm)) / (2.0 * eps as f64);
            let rel = (fd - g[j] as f64).abs() / fd.abs().max(1.0);
            assert!(rel < 1e-2, "coord {j}: fd={fd} analytic={}", g[j]);
        }
    }

    #[test]
    fn parallel_grad_matrix_matches_serial_bitwise() {
        let mut rng = Rng::new(9);
        let ds = LinRegDataset::generate(40, 64, 0.5, &mut rng);
        let x = rng.gauss_vec(64);
        let mut a = Mat::zeros(40, 64);
        let mut b = Mat::zeros(40, 64);
        ds.grad_matrix(&x, &mut a);
        ds.grad_matrix_par(&x, &mut b, Parallelism::new(8));
        assert_eq!(a.data, b.data);
        let pool = Pool::new(8);
        let mut c = Mat::zeros(40, 64);
        ds.grad_matrix_pool(&x, &mut c, &pool);
        assert_eq!(a.data, c.data);
        let mut ra = vec![0.0f32; 40];
        let mut rb = vec![0.0f32; 40];
        ds.residuals(&x, &mut ra);
        ds.residuals_par(&x, &mut rb, Parallelism::new(8));
        assert_eq!(ra, rb);
        let mut rc = vec![0.0f32; 40];
        ds.residuals_pool(&x, &mut rc, &pool);
        assert_eq!(ra, rc);
    }

    #[test]
    fn heterogeneity_grows_with_sigma_h() {
        let mut rng = Rng::new(7);
        let x = vec![0.0f32; 20];
        let ds0 = LinRegDataset::generate(50, 20, 0.0, &mut rng);
        let ds3 = LinRegDataset::generate(50, 20, 3.0, &mut rng);
        assert!(ds3.heterogeneity_at(&x) > ds0.heterogeneity_at(&x));
    }

    #[test]
    fn loss_decreases_along_negative_gradient() {
        let (ds, x) = small();
        let g = ds.full_grad(&x);
        let gn = crate::util::math::norm_sq(&g);
        let step = 1e-6f32;
        let x2: Vec<f32> = x.iter().zip(&g).map(|(xi, gi)| xi - step * gi).collect();
        assert!(ds.loss(&x2) < ds.loss(&x), "gn={gn}");
    }
}
