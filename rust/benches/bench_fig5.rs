//! Regenerates Fig. 5 (loss vs iterations under σ_H ∈ {0, 0.1}) and prints
//! the per-heterogeneity final-loss tables.

use lad::experiments::fig5;
use lad::util::timer::Timer;

fn main() {
    let full = std::env::var("LAD_BENCH_FULL").is_ok();
    let mut p = fig5::Fig5Params::default();
    if !full {
        p.iters = 800;
    }
    println!(
        "=== Fig. 5 reproduction (B=20, d={}, T={}) — LAD gain vs heterogeneity ===",
        p.d, p.iters
    );
    let t = Timer::start();
    for out in fig5::run(&p).expect("fig5") {
        out.print_table();
        // the paper's claim: the LAD/CWTM gap widens with sigma_H
        let fin = |label: &str| -> f64 {
            *out.series.iter().find(|s| s.label == label).unwrap().y.last().unwrap()
        };
        println!(
            "  -> gain (cwtm / lad-cwtm) = {:.3}x",
            fin("cwtm") / fin("lad-cwtm")
        );
    }
    println!("\ntotal wall: {:.1}s", t.elapsed_s());
}
