//! Ablations over the design choices DESIGN.md calls out:
//!  1. task-matrix layout (cyclic vs fractional-repetition vs random) —
//!     Lemma 1 in practice;
//!  2. unbiased vs biased compression (rand-K vs top-K vs QSGD) inside
//!     Com-LAD — why Definition 2 demands unbiasedness;
//!  3. aggregator zoo under coding — the meta-algorithm claim.

use lad::coding::task_matrix::lemma1_infimum;
use lad::coding::TaskMatrix;
use lad::config::{AggregatorKind, AttackKind, CompressionKind, TrainConfig};
use lad::data::linreg::LinRegDataset;
use lad::experiments::common::{run_variant, Variant};
use lad::util::rng::Rng;

fn main() {
    ablation_task_matrix();
    ablation_compression();
    ablation_aggregators();
}

fn ablation_task_matrix() {
    println!("=== ablation 1: task-matrix layout (Lemma 1) ===");
    let (n, h, d) = (100usize, 80usize, 10usize);
    let mut rng = Rng::new(1);
    let cyc = TaskMatrix::cyclic(n, d).lemma1_objective(h);
    let fr = TaskMatrix::fractional_repetition(n, d).lemma1_objective(h);
    let rand = TaskMatrix::random(n, d, &mut rng).lemma1_objective(h);
    let inf = lemma1_infimum(n, h, d);
    println!("  infimum (paper eq. 17): {inf:.6e}");
    println!("  cyclic                : {cyc:.6e}  (matches infimum)");
    println!("  fractional repetition : {fr:.6e}");
    println!("  random d-regular      : {rand:.6e}");
    assert!(cyc <= fr && cyc <= rand);
}

fn ablation_compression() {
    println!("\n=== ablation 2: compression operators inside Com-LAD ===");
    let mut rng = Rng::new(2);
    let ds = LinRegDataset::generate(60, 60, 0.3, &mut rng);
    for (label, comp) in [
        ("none (dense)", CompressionKind::None),
        ("rand-k 30% (unbiased)", CompressionKind::RandK { k: 18 }),
        ("top-k 30% (biased)", CompressionKind::TopK { k: 18 }),
        ("qsgd-16 (unbiased)", CompressionKind::Qsgd { levels: 16 }),
    ] {
        let mut cfg = TrainConfig::default();
        cfg.n_devices = 60;
        cfg.n_honest = 45;
        cfg.d = 5;
        cfg.dim = 60;
        cfg.iters = 1500;
        cfg.lr = 2e-5;
        cfg.sigma_h = 0.3;
        cfg.compression = comp;
        cfg.log_every = 0;
        let tr = run_variant(&ds, &Variant { label: label.into(), cfg, draco_r: None }, 3)
            .expect("run");
        println!(
            "  {label:<24} final_loss {:.4e}   uplink {:.2e} bits",
            tr.final_loss,
            tr.total_bits() as f64
        );
    }
}

fn ablation_aggregators() {
    println!("\n=== ablation 3: aggregator zoo, d=1 vs d=10 (sign-flip) ===");
    let mut rng = Rng::new(3);
    let ds = LinRegDataset::generate(60, 60, 0.3, &mut rng);
    println!("  {:<12} {:>14} {:>14} {:>8}", "rule", "d=1", "d=10 (LAD)", "gain");
    for kind in [
        AggregatorKind::Cwtm,
        AggregatorKind::Median,
        AggregatorKind::GeometricMedian,
        AggregatorKind::MultiKrum,
        AggregatorKind::Faba,
        AggregatorKind::Mcc,
    ] {
        let mut fin = [0.0f64; 2];
        for (i, d) in [1usize, 10].iter().enumerate() {
            let mut cfg = TrainConfig::default();
            cfg.n_devices = 60;
            cfg.n_honest = 48;
            cfg.d = *d;
            cfg.dim = 60;
            cfg.iters = 1500;
            cfg.lr = 2e-5;
            cfg.sigma_h = 0.3;
            cfg.aggregator = kind;
            cfg.attack = AttackKind::SignFlip { coeff: -2.0 };
            cfg.log_every = 0;
            fin[i] = run_variant(&ds, &Variant { label: "x".into(), cfg, draco_r: None }, 5)
                .expect("run")
                .final_loss;
        }
        println!(
            "  {:<12} {:>14.4e} {:>14.4e} {:>7.2}x",
            kind.name(),
            fin[0],
            fin[1],
            fin[0] / fin[1]
        );
    }
}
