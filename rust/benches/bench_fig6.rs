//! Regenerates Fig. 6 (compressed communication, rand-K Q̂=30) and prints
//! the final-loss table plus the per-method uplink bits.

use lad::experiments::fig6;
use lad::util::timer::Timer;

fn main() {
    let full = std::env::var("LAD_BENCH_FULL").is_ok();
    let mut p = fig6::Fig6Params::default();
    if !full {
        p.iters = 800;
    }
    println!(
        "=== Fig. 6 reproduction (N={}, H={}, rand-K Q̂={}, d={}, T={}) ===",
        p.n, p.h, p.q_hat, p.d, p.iters
    );
    let t = Timer::start();
    let out = fig6::run(&p).expect("fig6");
    out.print_table();
    let dense_bits = (p.n * p.q * 32 * p.iters) as f64;
    let sparse_bits = (p.n * p.q_hat * (32 + 7) * p.iters) as f64;
    println!(
        "\nuplink: dense {:.2e} bits vs rand-K {:.2e} bits ({:.1}% of dense)",
        dense_bits,
        sparse_bits,
        100.0 * sparse_bits / dense_bits
    );
    println!("total wall: {:.1}s", t.elapsed_s());
}
