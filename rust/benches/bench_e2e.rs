//! End-to-end bench: per-iteration cost of the full LAD transformer stack
//! (PJRT gradient computes + coding + attack + CWTM-NNM aggregation), and
//! the breakdown between runtime execution and coordinator overhead.

use lad::experiments::e2e::{run_default, E2eParams};
use lad::runtime::Runtime;

fn main() {
    let dir = std::env::var("LAD_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let Ok(mut rt) = Runtime::load(&dir) else {
        eprintln!("no artifacts at {dir} — run `make artifacts` first");
        return;
    };
    let mut p = E2eParams::default();
    p.iters = 6;
    p.log_every = 2;
    println!(
        "=== e2e LAD transformer: N={} devices, d={}, byz={}, {} iters ===",
        p.n_devices,
        p.d,
        p.n_devices - p.n_honest,
        p.iters
    );
    let trace = run_default(&mut rt, &p).expect("e2e");
    let execs = rt.stats.executes;
    let exec_s = rt.stats.execute_s;
    let compile_s = rt.stats.compile_s;
    let overhead = (trace.wall_s - exec_s - compile_s).max(0.0);
    println!("{}", trace.summary());
    println!(
        "PJRT: {execs} executes, {exec_s:.2}s total ({:.1} ms/exec); \
         one-time compile {compile_s:.2}s; coordinator overhead {overhead:.2}s \
         ({:.1}% of steady-state wall)",
        1e3 * exec_s / execs.max(1) as f64,
        100.0 * overhead / (trace.wall_s - compile_s).max(1e-9)
    );
    println!(
        "per-iteration: {:.2}s wall, {} PJRT calls",
        trace.wall_s / p.iters as f64,
        p.n_devices * p.d
    );
}
