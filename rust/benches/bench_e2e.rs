//! End-to-end bench, two parts:
//!
//! 1. **Native LAD stack scaling** (always runs): one full Com-LAD training
//!    job — coded gradients, sign-flip attack, rand-K compression,
//!    CWTM-NNM aggregation — at `threads = 1` vs `threads = all cores`.
//!    The two runs are bit-identical (asserted) so the wall-clock ratio is
//!    a pure measurement of the `util::parallel` engine.
//! 2. **PJRT transformer e2e** (needs `make artifacts` + `--features
//!    pjrt`): per-iteration cost of the full AOT path and the breakdown
//!    between runtime execution and coordinator overhead.

use lad::config::{AggregatorKind, AttackKind, CompressionKind, TrainConfig};
use lad::data::linreg::LinRegDataset;
use lad::experiments::common::{run_variant_in, Variant};
use lad::experiments::e2e::{run_default, E2eParams};
use lad::runtime::Runtime;
use lad::util::parallel::{available_threads, Pool};
use lad::util::rng::Rng;

fn native_stack_scaling() {
    let cores = available_threads();
    let mut cfg = TrainConfig::default();
    cfg.n_devices = 64;
    cfg.n_honest = 48;
    cfg.d = 8;
    cfg.dim = 4096;
    cfg.iters = 25;
    cfg.lr = 1e-8;
    cfg.sigma_h = 0.3;
    cfg.aggregator = AggregatorKind::Cwtm;
    cfg.nnm = true;
    cfg.trim_frac = 0.1;
    cfg.attack = AttackKind::SignFlip { coeff: -2.0 };
    cfg.compression = CompressionKind::RandK { k: 1024 };
    cfg.log_every = 0;
    println!(
        "=== native Com-LAD stack: N={} d={} Q={} T={} (CWTM-NNM, rand-K, sign-flip) ===",
        cfg.n_devices, cfg.d, cfg.dim, cfg.iters
    );
    let mut rng = Rng::new(97);
    let ds = LinRegDataset::generate(cfg.n_devices, cfg.dim, cfg.sigma_h, &mut rng);

    // One process-level budget for the whole sweep: each leg borrows a
    // width-capped slice of the same worker set instead of spawning a
    // private pool per variant (threads never alter a trace, so the
    // serial-vs-threaded bit-identity assertion below still bites).
    let budget = Pool::budgeted(cores, 1);
    let mut walls = Vec::new();
    let mut traces = Vec::new();
    for threads in [1usize, cores] {
        let mut c = cfg.clone();
        c.threads = threads;
        let v = Variant { label: format!("{threads}t"), cfg: c, draco_r: None };
        let tr =
            run_variant_in(&ds, &v, 98, &budget.inner_capped(threads)).expect("native stack run");
        println!(
            "  threads={threads:<3} wall {:8.3}s  final_loss {:.6e}",
            tr.wall_s, tr.final_loss
        );
        walls.push(tr.wall_s);
        traces.push(tr);
    }
    // the determinism contract, enforced where the perf numbers are made
    assert_eq!(traces[0].loss, traces[1].loss, "threaded trace diverged from serial");
    assert_eq!(traces[0].bits, traces[1].bits);
    println!(
        "  speedup {:.2}x with {} threads (bit-identical traces)",
        walls[0] / walls[1].max(1e-12),
        cores
    );
}

fn pjrt_e2e() {
    let dir = std::env::var("LAD_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let Ok(mut rt) = Runtime::load(&dir) else {
        eprintln!(
            "\nno artifacts at {dir} — skipping the PJRT e2e section (run `make artifacts`)"
        );
        return;
    };
    let mut p = E2eParams::default();
    p.iters = 6;
    p.log_every = 2;
    println!(
        "\n=== e2e LAD transformer: N={} devices, d={}, byz={}, {} iters ===",
        p.n_devices,
        p.d,
        p.n_devices - p.n_honest,
        p.iters
    );
    let trace = match run_default(&mut rt, &p) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("skipping the PJRT e2e section: {e:#}");
            return;
        }
    };
    let execs = rt.stats.executes;
    let exec_s = rt.stats.execute_s;
    let compile_s = rt.stats.compile_s;
    let overhead = (trace.wall_s - exec_s - compile_s).max(0.0);
    println!("{}", trace.summary());
    println!(
        "PJRT: {execs} executes, {exec_s:.2}s total ({:.1} ms/exec); \
         one-time compile {compile_s:.2}s; coordinator overhead {overhead:.2}s \
         ({:.1}% of steady-state wall)",
        1e3 * exec_s / execs.max(1) as f64,
        100.0 * overhead / (trace.wall_s - compile_s).max(1e-9)
    );
    println!(
        "per-iteration: {:.2}s wall, {} PJRT calls",
        trace.wall_s / p.iters as f64,
        p.n_devices * p.d
    );
}

fn main() {
    native_stack_scaling();
    pjrt_e2e();
}
