//! End-to-end bench, three parts:
//!
//! 1. **Native LAD stack scaling** (always runs): one full Com-LAD training
//!    job — coded gradients, sign-flip attack, rand-K compression,
//!    CWTM-NNM aggregation — at `threads = 1` vs `threads = all cores`.
//!    The two runs are bit-identical (asserted) so the wall-clock ratio is
//!    a pure measurement of the `util::parallel` engine.
//! 2. **Pipelined vs phase-serial cluster loop** (always runs): the
//!    8-worker loopback scenario — a real leader/worker cluster over
//!    in-process channel transports — once with the legacy phase-serial
//!    leader (`pipeline: false`: per-device `Msg::Broadcast` encoding on
//!    one thread) and once pipelined (shared x-frame prefix encoded once,
//!    per-device tails spliced on the pool, staged t+1 assignment, slab
//!    decode). Traces are asserted bit-identical, so the wall-clock ratio
//!    and the per-phase `broadcast/gather/aggregate` columns measure pure
//!    scheduling. This is the leg the committed `BENCH_e2e.json` baseline
//!    tracks.
//! 3. **PJRT transformer e2e** (needs `make artifacts` + `--features
//!    pjrt`): per-iteration cost of the full AOT path and the breakdown
//!    between runtime execution and coordinator overhead.
//!
//! Machine-readable results go to `BENCH_e2e.json` at the repository root.
//! If a committed baseline is present it is read **before** being
//! overwritten and the fresh pipelined-vs-serial speedup is diffed against
//! it within a tolerance band — a warning by default, a hard failure with
//! `LAD_BENCH_ENFORCE=1` (the CI bench leg). `LAD_BENCH_QUICK=1` shrinks
//! the workload for smoke runs.

use lad::config::{AggregatorKind, AttackKind, CompressionKind, TrainConfig};
use lad::data::linreg::LinRegDataset;
use lad::experiments::common::{run_variant_in, Variant};
use lad::experiments::e2e::{run_default, E2eParams};
use lad::net::LeaderOpts;
use lad::runtime::Runtime;
use lad::server::cluster::{run_cluster_with, ClusterOpts};
use lad::server::TrainTrace;
use lad::util::json::{self, Json};
use lad::util::parallel::{available_threads, Pool};
use lad::util::rng::Rng;
use std::collections::BTreeMap;

/// Fraction of the baseline pipelined-vs-serial speedup the fresh run must
/// retain before the diff counts as a regression (wall-clock noise band).
const BASELINE_TOLERANCE: f64 = 0.8;

fn quick() -> bool {
    std::env::var("LAD_BENCH_QUICK").map(|v| v == "1").unwrap_or(false)
}

fn entry(section: &str, leg: &str, tr: &TrainTrace, speedup: f64) -> Json {
    let mut o = BTreeMap::new();
    o.insert("section".into(), Json::Str(section.into()));
    o.insert("leg".into(), Json::Str(leg.into()));
    o.insert("wall_s".into(), Json::Num(tr.wall_s));
    o.insert("broadcast_ms".into(), Json::Num(tr.broadcast_ns as f64 / 1e6));
    o.insert("gather_ms".into(), Json::Num(tr.gather_ns as f64 / 1e6));
    o.insert("aggregate_ms".into(), Json::Num(tr.aggregate_ns as f64 / 1e6));
    o.insert("wire_up_bytes".into(), Json::Num(tr.wire_up_bytes as f64));
    o.insert("wire_down_bytes".into(), Json::Num(tr.wire_down_bytes as f64));
    o.insert("speedup_vs_serial".into(), Json::Num(speedup));
    Json::Obj(o)
}

fn native_stack_scaling(entries: &mut Vec<Json>) {
    let cores = available_threads();
    let mut cfg = TrainConfig::default();
    cfg.n_devices = 64;
    cfg.n_honest = 48;
    cfg.d = 8;
    cfg.dim = if quick() { 1024 } else { 4096 };
    cfg.iters = if quick() { 8 } else { 25 };
    cfg.lr = 1e-8;
    cfg.sigma_h = 0.3;
    cfg.aggregator = AggregatorKind::Cwtm;
    cfg.nnm = true;
    cfg.trim_frac = 0.1;
    cfg.attack = AttackKind::SignFlip { coeff: -2.0 };
    cfg.compression = CompressionKind::RandK { k: cfg.dim / 4 };
    cfg.log_every = 0;
    println!(
        "=== native Com-LAD stack: N={} d={} Q={} T={} (CWTM-NNM, rand-K, sign-flip) ===",
        cfg.n_devices, cfg.d, cfg.dim, cfg.iters
    );
    let mut rng = Rng::new(97);
    let ds = LinRegDataset::generate(cfg.n_devices, cfg.dim, cfg.sigma_h, &mut rng);

    // One process-level budget for the whole sweep: each leg borrows a
    // width-capped slice of the same worker set instead of spawning a
    // private pool per variant (threads never alter a trace, so the
    // serial-vs-threaded bit-identity assertion below still bites).
    let budget = Pool::budgeted(cores, 1);
    let mut walls = Vec::new();
    let mut traces = Vec::new();
    for threads in [1usize, cores] {
        let mut c = cfg.clone();
        c.threads = threads;
        let v = Variant { label: format!("{threads}t"), cfg: c, draco_r: None };
        let tr =
            run_variant_in(&ds, &v, 98, &budget.inner_capped(threads)).expect("native stack run");
        println!(
            "  threads={threads:<3} wall {:8.3}s  final_loss {:.6e}",
            tr.wall_s, tr.final_loss
        );
        walls.push(tr.wall_s);
        traces.push(tr);
    }
    // the determinism contract, enforced where the perf numbers are made
    assert_eq!(traces[0].loss, traces[1].loss, "threaded trace diverged from serial");
    assert_eq!(traces[0].bits, traces[1].bits);
    let speedup = walls[0] / walls[1].max(1e-12);
    println!("  speedup {speedup:.2}x with {cores} threads (bit-identical traces)");
    entries.push(entry("native-scaling", "1t", &traces[0], 1.0));
    entries.push(entry("native-scaling", &format!("{cores}t"), &traces[1], speedup));
}

/// The 8-worker loopback scenario behind the committed baseline: identical
/// cluster runs with `pipeline` off (phase-serial reference) and on.
/// Returns the pipelined-vs-serial wall speedup.
fn cluster_pipeline_section(entries: &mut Vec<Json>) -> f64 {
    let mut cfg = TrainConfig::default();
    cfg.n_devices = 8;
    cfg.n_honest = 6;
    cfg.d = 1;
    cfg.dim = if quick() { 8_192 } else { 65_536 };
    cfg.iters = if quick() { 10 } else { 40 };
    cfg.lr = 1e-8;
    cfg.sigma_h = 0.3;
    cfg.aggregator = AggregatorKind::Cwtm;
    cfg.trim_frac = 0.1;
    cfg.attack = AttackKind::SignFlip { coeff: -2.0 };
    cfg.compression = CompressionKind::None;
    cfg.log_every = 0;
    cfg.threads = 0; // all cores for the leader's pooled stages
    println!(
        "\n=== loopback cluster: {} workers, Q={}, T={} — phase-serial vs pipelined ===",
        cfg.n_devices, cfg.dim, cfg.iters
    );
    let mut rng = Rng::new(101);
    let ds = LinRegDataset::generate(cfg.n_devices, cfg.dim, cfg.sigma_h, &mut rng);
    let pool = Pool::new(cfg.threads);
    let reps = if quick() { 2 } else { 3 };
    let mut best: Vec<TrainTrace> = Vec::new();
    for (leg, pipeline) in [("phase-serial", false), ("pipelined", true)] {
        let mut leg_best: Option<TrainTrace> = None;
        for _ in 0..reps {
            let agg = lad::aggregation::from_config_pooled(&cfg, &pool);
            let atk = lad::attack::from_kind(cfg.attack);
            let comp = lad::compress::from_kind(cfg.compression);
            let opts = ClusterOpts {
                leader: LeaderOpts { pipeline, ..Default::default() },
                ..Default::default()
            };
            let mut x0 = vec![0.0f32; cfg.dim];
            let tr = run_cluster_with(
                &cfg,
                &ds,
                agg.as_ref(),
                atk.as_ref(),
                comp.as_ref(),
                &mut x0,
                leg,
                &mut Rng::new(102),
                &pool,
                &opts,
            )
            .expect("loopback cluster run");
            if leg_best.as_ref().map(|b| tr.wall_s < b.wall_s).unwrap_or(true) {
                leg_best = Some(tr);
            }
        }
        let tr = leg_best.expect("at least one rep");
        println!(
            "  {leg:<13} wall {:7.3}s  bcast {:7.1}ms  gather {:7.1}ms  agg {:7.1}ms",
            tr.wall_s,
            tr.broadcast_ns as f64 / 1e6,
            tr.gather_ns as f64 / 1e6,
            tr.aggregate_ns as f64 / 1e6
        );
        best.push(tr);
    }
    // the hard gate: pipelining is pure scheduling, the traces must match
    assert_eq!(best[0].loss, best[1].loss, "pipelined trace diverged from phase-serial");
    assert_eq!(best[0].bits, best[1].bits);
    assert_eq!(best[0].wire_up_bytes, best[1].wire_up_bytes);
    assert_eq!(best[0].wire_down_bytes, best[1].wire_down_bytes);
    let speedup = best[0].wall_s / best[1].wall_s.max(1e-12);
    println!("  pipelined speedup {speedup:.2}x (bit-identical traces + wire bytes)");
    entries.push(entry("cluster-loopback", "phase-serial", &best[0], 1.0));
    entries.push(entry("cluster-loopback", "pipelined", &best[1], speedup));
    speedup
}

fn pjrt_e2e() {
    let dir = std::env::var("LAD_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let Ok(mut rt) = Runtime::load(&dir) else {
        eprintln!(
            "\nno artifacts at {dir} — skipping the PJRT e2e section (run `make artifacts`)"
        );
        return;
    };
    let mut p = E2eParams::default();
    p.iters = 6;
    p.log_every = 2;
    println!(
        "\n=== e2e LAD transformer: N={} devices, d={}, byz={}, {} iters ===",
        p.n_devices,
        p.d,
        p.n_devices - p.n_honest,
        p.iters
    );
    let trace = match run_default(&mut rt, &p) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("skipping the PJRT e2e section: {e:#}");
            return;
        }
    };
    let execs = rt.stats.executes;
    let exec_s = rt.stats.execute_s;
    let compile_s = rt.stats.compile_s;
    let overhead = (trace.wall_s - exec_s - compile_s).max(0.0);
    println!("{}", trace.summary());
    println!(
        "PJRT: {execs} executes, {exec_s:.2}s total ({:.1} ms/exec); \
         one-time compile {compile_s:.2}s; coordinator overhead {overhead:.2}s \
         ({:.1}% of steady-state wall)",
        1e3 * exec_s / execs.max(1) as f64,
        100.0 * overhead / (trace.wall_s - compile_s).max(1e-9)
    );
    println!(
        "per-iteration: {:.2}s wall, {} PJRT calls",
        trace.wall_s / p.iters as f64,
        p.n_devices * p.d
    );
}

/// Pull the pipelined leg's `speedup_vs_serial` out of a baseline JSON.
fn baseline_speedup(body: &str) -> Option<f64> {
    let root = json::parse(body).ok()?;
    root.get("entries")?.as_arr()?.iter().find_map(|e| {
        (e.get("section")?.as_str()? == "cluster-loopback"
            && e.get("leg")?.as_str()? == "pipelined")
            .then(|| e.get("speedup_vs_serial")?.as_f64())
            .flatten()
    })
}

fn main() {
    let mut entries: Vec<Json> = Vec::new();
    native_stack_scaling(&mut entries);
    let speedup = cluster_pipeline_section(&mut entries);
    pjrt_e2e();

    // read the committed baseline BEFORE overwriting it, then dump the
    // fresh snapshot (the CI bench leg uploads it as an artifact; commit
    // that artifact at the repo root to advance the baseline)
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_e2e.json");
    let baseline = std::fs::read_to_string(path).ok();
    let mut root = BTreeMap::new();
    root.insert("bench".into(), Json::Str("e2e".into()));
    root.insert("threads".into(), Json::Num(available_threads() as f64));
    root.insert("quick".into(), Json::Bool(quick()));
    root.insert("entries".into(), Json::Arr(entries));
    match std::fs::write(path, Json::Obj(root).to_pretty_string() + "\n") {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("\ncould not write {path}: {e}"),
    }
    match baseline.as_deref().and_then(baseline_speedup) {
        None => println!("no committed BENCH_e2e.json baseline — skipping the tolerance diff"),
        Some(base) => {
            let floor = base * BASELINE_TOLERANCE;
            println!(
                "baseline pipelined speedup {base:.2}x — fresh {speedup:.2}x \
                 (tolerance floor {floor:.2}x)"
            );
            if speedup < floor {
                let msg = format!(
                    "pipelined speedup regressed below the tolerance band: \
                     {speedup:.2}x < {floor:.2}x ({}% of baseline {base:.2}x)",
                    (BASELINE_TOLERANCE * 100.0) as u32
                );
                if std::env::var("LAD_BENCH_ENFORCE").map(|v| v == "1").unwrap_or(false) {
                    panic!("{msg}");
                }
                eprintln!("warning: {msg}");
            }
        }
    }
}
