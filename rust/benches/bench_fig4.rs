//! Regenerates Fig. 4 (loss vs iterations, sign-flip, no compression) at a
//! bench-friendly horizon and prints the final-loss table the paper's
//! figure implies. Set LAD_BENCH_FULL=1 for the full 3000-iteration run.

use lad::experiments::fig4;
use lad::util::timer::Timer;

fn main() {
    let full = std::env::var("LAD_BENCH_FULL").is_ok();
    let mut p = fig4::Fig4Params::default();
    if !full {
        p.iters = 800;
    }
    println!(
        "=== Fig. 4 reproduction (N={}, H={}, sign-flip -2, sigma_H={}, T={}) ===",
        p.n, p.h, p.sigma_h, p.iters
    );
    let t = Timer::start();
    let out = fig4::run(&p).expect("fig4");
    out.print_table();
    println!("\ntotal wall: {:.1}s  (LAD_BENCH_FULL=1 for T=3000)", t.elapsed_s());
}
