//! Micro-bench: aggregation rules at the paper's scale (N=100, Q=100) and
//! at transformer scale (N=8, Q=0.4M) — the L3 hot path — plus the
//! per-rule execution-strategy comparison for the O(N²Q) pairwise-distance
//! rules (Krum, Multi-Krum, NNM): serial shared-Gram pass vs scoped spawns
//! vs the persistent worker pool, all bit-identical by construction.
//!
//! Two kernel-stack sections track the PR 3 work: a **per-tier** kernel
//! table (scalar vs SSE2 vs AVX2+FMA `dot`/`dist_sq`/`norm_sq`, every tier
//! the CPU can run) and a **storage footprint** line for the
//! packed-triangular `PairwiseDistances` vs the full symmetric matrix it
//! replaced.
//!
//! Machine-readable results are written to `BENCH_aggregation.json` at the
//! repository root (one snapshot per run; commit it per PR to track the
//! perf trajectory — the CI simd matrix uploads one artifact per pinned
//! tier). Set `LAD_BENCH_QUICK=1` (the CI smoke mode) to shrink budgets and
//! skip the transformer-scale section; set `LAD_SIMD_TIER` to pin the
//! dispatched tier the rule sections run under.

use lad::aggregation::gram::PairwiseDistances;
use lad::aggregation::{
    Aggregator, CoordinateMedian, Cwtm, Faba, GeometricMedian, Krum, Mcc, Mean, MultiKrum, Nnm,
    Tgn,
};
use lad::bench_support::{run, section, BenchResult};
use lad::util::json::Json;
use lad::util::math::{self, Tier};
use lad::util::parallel::{available_threads, Parallelism, Pool};
use lad::util::rng::Rng;
use std::collections::BTreeMap;

fn family(n: usize, q: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| rng.gauss_vec(q)).collect()
}

fn quick() -> bool {
    std::env::var("LAD_BENCH_QUICK").map(|v| v == "1").unwrap_or(false)
}

fn budget(ms: f64) -> f64 {
    if quick() {
        ms / 8.0
    } else {
        ms
    }
}

/// One JSON record for `BENCH_aggregation.json`. `tier` is the kernel tier
/// the timed code ran on (the dispatched tier for rules, the explicit one
/// for kernel rows).
fn record(
    scale: &str,
    rule: &str,
    variant: &str,
    tier: Tier,
    r: &BenchResult,
    speedup: f64,
) -> Json {
    let mut o = BTreeMap::new();
    o.insert("scale".into(), Json::Str(scale.into()));
    o.insert("rule".into(), Json::Str(rule.into()));
    o.insert("variant".into(), Json::Str(variant.into()));
    o.insert("tier".into(), Json::Str(tier.name().into()));
    o.insert("median_ns".into(), Json::Num(r.median_ns));
    o.insert("min_ns".into(), Json::Num(r.min_ns));
    o.insert("p95_ns".into(), Json::Num(r.p95_ns));
    o.insert("speedup_vs_serial".into(), Json::Num(speedup));
    Json::Obj(o)
}

/// Per-tier kernel columns: the same `dot` / `dist_sq` / `norm_sq` workload
/// timed on every tier this CPU can execute, so the JSON trajectory shows
/// what each ladder rung buys. Each kernel gets its own scalar baseline
/// (the first detected tier is always scalar), and the timed closures go
/// through [`Tier::kernels_checked`] so the support assert is paid once
/// outside the loop — per-call cost matches the dispatched free functions.
fn tier_kernel_section(q: usize, entries: &mut Vec<Json>) {
    let scale = format!("kernel,Q={q}");
    section(&format!("kernel tiers, Q={q} (scalar vs SSE2 vs AVX2+FMA)"));
    let a = family(1, q, 11).pop().unwrap();
    let b = family(1, q, 12).pop().unwrap();
    // (dot, dist_sq, norm_sq) scalar medians
    let mut baseline: Option<(f64, f64, f64)> = None;
    for tier in math::detected_tiers() {
        let n = tier.name();
        let k = tier.kernels_checked();
        let d = run(&format!("dot ({n})"), budget(60.0), || k.dot(&a, &b));
        let s = run(&format!("dist_sq ({n})"), budget(60.0), || k.dist_sq(&a, &b));
        let m = run(&format!("norm_sq ({n})"), budget(60.0), || k.norm_sq(&a));
        let (bd, bs, bm) = *baseline.get_or_insert((d.median_ns, s.median_ns, m.median_ns));
        println!(
            "      speedup vs scalar: dot {:.2}x, dist_sq {:.2}x, norm_sq {:.2}x (median)",
            bd / d.median_ns,
            bs / s.median_ns,
            bm / m.median_ns
        );
        entries.push(record(&scale, "dot", "kernel", tier, &d, bd / d.median_ns));
        entries.push(record(&scale, "dist_sq", "kernel", tier, &s, bs / s.median_ns));
        entries.push(record(&scale, "norm_sq", "kernel", tier, &m, bm / m.median_ns));
    }
}

/// The packed-triangular storage footprint vs the full symmetric matrix the
/// kernel stored before — one JSON line per N so the trajectory records the
/// halving.
fn storage_footprint_section(entries: &mut Vec<Json>) {
    section("PairwiseDistances storage: packed triangle vs full N×N");
    for n in [100usize, 1000] {
        let msgs = family(n, 4, 21);
        let pd = PairwiseDistances::compute(&msgs, &Pool::serial());
        let packed = pd.packed_bytes();
        let full = pd.full_bytes_equivalent();
        println!(
            "  N={n:<5} packed {packed:>9} B   full-equivalent {full:>9} B   ratio {:.3}",
            packed as f64 / full as f64
        );
        let mut o = BTreeMap::new();
        o.insert("kind".into(), Json::Str("storage".into()));
        o.insert("n".into(), Json::Num(n as f64));
        o.insert("packed_bytes".into(), Json::Num(packed as f64));
        o.insert("full_bytes".into(), Json::Num(full as f64));
        o.insert("ratio".into(), Json::Num(packed as f64 / full as f64));
        entries.push(Json::Obj(o));
    }
}

/// Serial vs scoped-spawn vs persistent-pool comparison for the
/// distance-bound rules; the sanity assert keeps the bit-identical contract
/// in the bench loop itself.
fn strategy_section(
    scale: &str,
    msgs: &[Vec<f32>],
    f: usize,
    pool: &Pool,
    entries: &mut Vec<Json>,
) {
    let t = pool.threads();
    let tier = math::active_tier();
    section(&format!("{scale} — pairwise rules: serial vs scoped({t}) vs pool({t})"));
    let scoped = Parallelism::new(t);
    let rules: Vec<(&str, Box<dyn Aggregator>, Box<dyn Aggregator>, Box<dyn Aggregator>)> = vec![
        (
            "krum",
            Box::new(Krum::new(f)),
            Box::new(Krum::new(f).with_parallelism(scoped)),
            Box::new(Krum::new(f).with_pool(pool)),
        ),
        (
            "multi-krum",
            Box::new(MultiKrum::new(f)),
            Box::new(MultiKrum::new(f).with_parallelism(scoped)),
            Box::new(MultiKrum::new(f).with_pool(pool)),
        ),
        (
            "cwtm-nnm",
            Box::new(Nnm::new(f, Box::new(Cwtm::new(0.1)))),
            Box::new(Nnm::new(f, Box::new(Cwtm::new(0.1))).with_parallelism(scoped)),
            Box::new(Nnm::new(f, Box::new(Cwtm::new(0.1))).with_pool(pool)),
        ),
    ];
    for (name, serial, scoped, pooled) in &rules {
        // sanity first: all strategies must agree bit-for-bit
        let want = serial.aggregate(msgs);
        assert_eq!(want, scoped.aggregate(msgs), "{name}: scoped != serial");
        assert_eq!(want, pooled.aggregate(msgs), "{name}: pool != serial");
        let s = run(&format!("{name} (serial gram)"), budget(200.0), || serial.aggregate(msgs));
        let c = run(&format!("{name} (scoped {t}t)"), budget(200.0), || scoped.aggregate(msgs));
        let p = run(&format!("{name} (pool {t}t)"), budget(200.0), || pooled.aggregate(msgs));
        println!(
            "      speedup vs serial: scoped {:.2}x, pool {:.2}x (median)",
            s.median_ns / c.median_ns,
            s.median_ns / p.median_ns
        );
        entries.push(record(scale, name, "serial", tier, &s, 1.0));
        entries.push(record(scale, name, "scoped", tier, &c, s.median_ns / c.median_ns));
        entries.push(record(scale, name, "pool", tier, &p, s.median_ns / p.median_ns));
    }
}

fn main() {
    let mut entries: Vec<Json> = Vec::new();
    let tier = math::active_tier();
    println!("kernel tier: {} (dispatched; LAD_SIMD_TIER pins)", tier.name());

    // per-tier kernel columns + the packed-storage footprint first — these
    // are the PR-over-PR trajectory rows
    tier_kernel_section(100, &mut entries);
    if !quick() {
        tier_kernel_section(409_000, &mut entries);
    }
    storage_footprint_section(&mut entries);

    section("aggregation rules, N=100 Q=100 (paper scale)");
    let msgs = family(100, 100, 1);
    let rules: Vec<Box<dyn Aggregator>> = vec![
        Box::new(Mean),
        Box::new(Cwtm::new(0.1)),
        Box::new(CoordinateMedian),
        Box::new(GeometricMedian::default()),
        Box::new(Krum::new(20)),
        Box::new(MultiKrum::new(20)),
        Box::new(Mcc::default()),
        Box::new(Faba::new(20)),
        Box::new(Tgn::new(0.2)),
        Box::new(Nnm::new(20, Box::new(Cwtm::new(0.1)))),
    ];
    for rule in &rules {
        let r = run(&rule.name(), budget(150.0), || rule.aggregate(&msgs));
        entries.push(record("N=100,Q=100", &rule.name(), "serial", tier, &r, 1.0));
    }

    let big = if quick() {
        Vec::new()
    } else {
        section("aggregation rules, N=8 Q=409k (e2e transformer scale)");
        let big = family(8, 409_000, 2);
        for rule in &rules {
            let r = run(&rule.name(), budget(250.0), || rule.aggregate(&big));
            entries.push(record("N=8,Q=409k", &rule.name(), "serial", tier, &r, 1.0));
        }
        big
    };

    // strategy comparison: the dense-N case (distance matrix bound), the
    // fat-Q case (few rows, huge dot products), and transformer scale
    let pool = Pool::new(0);
    strategy_section("N=100,Q=100", &msgs, 20, &pool, &mut entries);
    let wide = family(100, 4096, 3);
    strategy_section("N=100,Q=4096", &wide, 20, &pool, &mut entries);
    if !quick() {
        strategy_section("N=8,Q=409k", &big, 2, &pool, &mut entries);
    }

    // machine-readable dump at the repo root (perf trajectory across PRs)
    let mut root = BTreeMap::new();
    root.insert("bench".into(), Json::Str("aggregation".into()));
    root.insert("threads".into(), Json::Num(available_threads() as f64));
    root.insert("simd".into(), Json::Bool(lad::util::math::SIMD_ACTIVE));
    root.insert("tier".into(), Json::Str(tier.name().into()));
    root.insert(
        "tiers_detected".into(),
        Json::Arr(math::detected_tiers().iter().map(|t| Json::Str(t.name().into())).collect()),
    );
    root.insert("quick".into(), Json::Bool(quick()));
    root.insert("entries".into(), Json::Arr(entries));
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_aggregation.json");
    match std::fs::write(path, Json::Obj(root).to_pretty_string() + "\n") {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("\ncould not write {path}: {e}"),
    }
}
