//! Micro-bench: aggregation rules at the paper's scale (N=100, Q=100) and
//! at transformer scale (N=8, Q=0.4M) — the L3 hot path — plus the
//! serial-vs-threaded comparison for the O(N²Q) pairwise-distance rules
//! (Krum, Multi-Krum, NNM), whose parallel pass is bit-identical to serial.

use lad::aggregation::{
    Aggregator, CoordinateMedian, Cwtm, Faba, GeometricMedian, Krum, Mcc, Mean, MultiKrum, Nnm,
    Tgn,
};
use lad::bench_support::{run, section};
use lad::util::parallel::Parallelism;
use lad::util::rng::Rng;

fn family(n: usize, q: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| rng.gauss_vec(q)).collect()
}

fn threaded_pairwise_section(title: &str, msgs: &[Vec<f32>], f: usize) {
    let par = Parallelism::auto();
    let t = par.threads();
    section(&format!("{title} — pairwise rules, serial vs {t} threads"));
    let pairs: Vec<(&str, Box<dyn Aggregator>, Box<dyn Aggregator>)> = vec![
        (
            "krum",
            Box::new(Krum::new(f)),
            Box::new(Krum::new(f).with_parallelism(par)),
        ),
        (
            "multi-krum",
            Box::new(MultiKrum::new(f)),
            Box::new(MultiKrum::new(f).with_parallelism(par)),
        ),
        (
            "cwtm-nnm",
            Box::new(Nnm::new(f, Box::new(Cwtm::new(0.1)))),
            Box::new(Nnm::new(f, Box::new(Cwtm::new(0.1))).with_parallelism(par)),
        ),
    ];
    for (name, serial, threaded) in &pairs {
        // sanity first: the two paths must agree bit-for-bit
        assert_eq!(
            serial.aggregate(msgs),
            threaded.aggregate(msgs),
            "{name}: parallel != serial"
        );
        let s = run(&format!("{name} (1 thread)"), 200.0, || serial.aggregate(msgs));
        let p = run(&format!("{name} ({t} threads)"), 200.0, || threaded.aggregate(msgs));
        println!("      speedup {:.2}x (median)", s.median_ns / p.median_ns);
    }
}

fn main() {
    section("aggregation rules, N=100 Q=100 (paper scale)");
    let msgs = family(100, 100, 1);
    let rules: Vec<Box<dyn Aggregator>> = vec![
        Box::new(Mean),
        Box::new(Cwtm::new(0.1)),
        Box::new(CoordinateMedian),
        Box::new(GeometricMedian::default()),
        Box::new(Krum::new(20)),
        Box::new(MultiKrum::new(20)),
        Box::new(Mcc::default()),
        Box::new(Faba::new(20)),
        Box::new(Tgn::new(0.2)),
        Box::new(Nnm::new(20, Box::new(Cwtm::new(0.1)))),
    ];
    for rule in &rules {
        run(&rule.name(), 150.0, || rule.aggregate(&msgs));
    }

    section("aggregation rules, N=8 Q=409k (e2e transformer scale)");
    let big = family(8, 409_000, 2);
    for rule in &rules {
        run(&rule.name(), 250.0, || rule.aggregate(&big));
    }

    // threaded variants: the dense-N case (distance matrix bound) and the
    // fat-Q case (few rows, huge dot products)
    threaded_pairwise_section("N=100 Q=100", &msgs, 20);
    let wide = family(100, 4096, 3);
    threaded_pairwise_section("N=100 Q=4096", &wide, 20);
    threaded_pairwise_section("N=8 Q=409k", &big, 2);
}
