//! Micro-bench: aggregation rules at the paper's scale (N=100, Q=100) and
//! at transformer scale (N=8, Q=0.4M) — the L3 hot path.

use lad::aggregation::{
    Aggregator, CoordinateMedian, Cwtm, Faba, GeometricMedian, Krum, Mcc, Mean, MultiKrum, Nnm,
    Tgn,
};
use lad::bench_support::{run, section};
use lad::util::rng::Rng;

fn family(n: usize, q: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| rng.gauss_vec(q)).collect()
}

fn main() {
    section("aggregation rules, N=100 Q=100 (paper scale)");
    let msgs = family(100, 100, 1);
    let rules: Vec<Box<dyn Aggregator>> = vec![
        Box::new(Mean),
        Box::new(Cwtm::new(0.1)),
        Box::new(CoordinateMedian),
        Box::new(GeometricMedian::default()),
        Box::new(Krum::new(20)),
        Box::new(MultiKrum::new(20)),
        Box::new(Mcc::default()),
        Box::new(Faba::new(20)),
        Box::new(Tgn::new(0.2)),
        Box::new(Nnm::new(20, Box::new(Cwtm::new(0.1)))),
    ];
    for rule in &rules {
        run(&rule.name(), 150.0, || rule.aggregate(&msgs));
    }

    section("aggregation rules, N=8 Q=409k (e2e transformer scale)");
    let big = family(8, 409_000, 2);
    for rule in &rules {
        run(&rule.name(), 250.0, || rule.aggregate(&big));
    }
}
