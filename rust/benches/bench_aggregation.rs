//! Micro-bench: aggregation rules at the paper's scale (N=100, Q=100) and
//! at transformer scale (N=8, Q=0.4M) — the L3 hot path — plus the
//! per-rule execution-strategy comparison for the O(N²Q) pairwise-distance
//! rules (Krum, Multi-Krum, NNM): serial shared-Gram pass vs scoped spawns
//! vs the persistent worker pool, all bit-identical by construction.
//!
//! Machine-readable results are written to `BENCH_aggregation.json` at the
//! repository root (one snapshot per run; commit it per PR to track the
//! perf trajectory). Set
//! `LAD_BENCH_QUICK=1` (the CI smoke mode) to shrink budgets and skip the
//! transformer-scale section.

use lad::aggregation::{
    Aggregator, CoordinateMedian, Cwtm, Faba, GeometricMedian, Krum, Mcc, Mean, MultiKrum, Nnm,
    Tgn,
};
use lad::bench_support::{run, section, BenchResult};
use lad::util::json::Json;
use lad::util::parallel::{available_threads, Parallelism, Pool};
use lad::util::rng::Rng;
use std::collections::BTreeMap;

fn family(n: usize, q: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| rng.gauss_vec(q)).collect()
}

fn quick() -> bool {
    std::env::var("LAD_BENCH_QUICK").map(|v| v == "1").unwrap_or(false)
}

fn budget(ms: f64) -> f64 {
    if quick() {
        ms / 8.0
    } else {
        ms
    }
}

/// One JSON record for `BENCH_aggregation.json`.
fn record(scale: &str, rule: &str, variant: &str, r: &BenchResult, speedup: f64) -> Json {
    let mut o = BTreeMap::new();
    o.insert("scale".into(), Json::Str(scale.into()));
    o.insert("rule".into(), Json::Str(rule.into()));
    o.insert("variant".into(), Json::Str(variant.into()));
    o.insert("median_ns".into(), Json::Num(r.median_ns));
    o.insert("min_ns".into(), Json::Num(r.min_ns));
    o.insert("p95_ns".into(), Json::Num(r.p95_ns));
    o.insert("speedup_vs_serial".into(), Json::Num(speedup));
    Json::Obj(o)
}

/// Serial vs scoped-spawn vs persistent-pool comparison for the
/// distance-bound rules; the sanity assert keeps the bit-identical contract
/// in the bench loop itself.
fn strategy_section(
    scale: &str,
    msgs: &[Vec<f32>],
    f: usize,
    pool: &Pool,
    entries: &mut Vec<Json>,
) {
    let t = pool.threads();
    section(&format!("{scale} — pairwise rules: serial vs scoped({t}) vs pool({t})"));
    let scoped = Parallelism::new(t);
    let rules: Vec<(&str, Box<dyn Aggregator>, Box<dyn Aggregator>, Box<dyn Aggregator>)> = vec![
        (
            "krum",
            Box::new(Krum::new(f)),
            Box::new(Krum::new(f).with_parallelism(scoped)),
            Box::new(Krum::new(f).with_pool(pool)),
        ),
        (
            "multi-krum",
            Box::new(MultiKrum::new(f)),
            Box::new(MultiKrum::new(f).with_parallelism(scoped)),
            Box::new(MultiKrum::new(f).with_pool(pool)),
        ),
        (
            "cwtm-nnm",
            Box::new(Nnm::new(f, Box::new(Cwtm::new(0.1)))),
            Box::new(Nnm::new(f, Box::new(Cwtm::new(0.1))).with_parallelism(scoped)),
            Box::new(Nnm::new(f, Box::new(Cwtm::new(0.1))).with_pool(pool)),
        ),
    ];
    for (name, serial, scoped, pooled) in &rules {
        // sanity first: all strategies must agree bit-for-bit
        let want = serial.aggregate(msgs);
        assert_eq!(want, scoped.aggregate(msgs), "{name}: scoped != serial");
        assert_eq!(want, pooled.aggregate(msgs), "{name}: pool != serial");
        let s = run(&format!("{name} (serial gram)"), budget(200.0), || serial.aggregate(msgs));
        let c = run(&format!("{name} (scoped {t}t)"), budget(200.0), || scoped.aggregate(msgs));
        let p = run(&format!("{name} (pool {t}t)"), budget(200.0), || pooled.aggregate(msgs));
        println!(
            "      speedup vs serial: scoped {:.2}x, pool {:.2}x (median)",
            s.median_ns / c.median_ns,
            s.median_ns / p.median_ns
        );
        entries.push(record(scale, name, "serial", &s, 1.0));
        entries.push(record(scale, name, "scoped", &c, s.median_ns / c.median_ns));
        entries.push(record(scale, name, "pool", &p, s.median_ns / p.median_ns));
    }
}

fn main() {
    let mut entries: Vec<Json> = Vec::new();

    section("aggregation rules, N=100 Q=100 (paper scale)");
    let msgs = family(100, 100, 1);
    let rules: Vec<Box<dyn Aggregator>> = vec![
        Box::new(Mean),
        Box::new(Cwtm::new(0.1)),
        Box::new(CoordinateMedian),
        Box::new(GeometricMedian::default()),
        Box::new(Krum::new(20)),
        Box::new(MultiKrum::new(20)),
        Box::new(Mcc::default()),
        Box::new(Faba::new(20)),
        Box::new(Tgn::new(0.2)),
        Box::new(Nnm::new(20, Box::new(Cwtm::new(0.1)))),
    ];
    for rule in &rules {
        let r = run(&rule.name(), budget(150.0), || rule.aggregate(&msgs));
        entries.push(record("N=100,Q=100", &rule.name(), "serial", &r, 1.0));
    }

    let big = if quick() {
        Vec::new()
    } else {
        section("aggregation rules, N=8 Q=409k (e2e transformer scale)");
        let big = family(8, 409_000, 2);
        for rule in &rules {
            let r = run(&rule.name(), budget(250.0), || rule.aggregate(&big));
            entries.push(record("N=8,Q=409k", &rule.name(), "serial", &r, 1.0));
        }
        big
    };

    // strategy comparison: the dense-N case (distance matrix bound), the
    // fat-Q case (few rows, huge dot products), and transformer scale
    let pool = Pool::new(0);
    strategy_section("N=100,Q=100", &msgs, 20, &pool, &mut entries);
    let wide = family(100, 4096, 3);
    strategy_section("N=100,Q=4096", &wide, 20, &pool, &mut entries);
    if !quick() {
        strategy_section("N=8,Q=409k", &big, 2, &pool, &mut entries);
    }

    // machine-readable dump at the repo root (perf trajectory across PRs)
    let mut root = BTreeMap::new();
    root.insert("bench".into(), Json::Str("aggregation".into()));
    root.insert("threads".into(), Json::Num(available_threads() as f64));
    root.insert("simd".into(), Json::Bool(lad::util::math::SIMD_ACTIVE));
    root.insert("quick".into(), Json::Bool(quick()));
    root.insert("entries".into(), Json::Arr(entries));
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_aggregation.json");
    match std::fs::write(path, Json::Obj(root).to_pretty_string() + "\n") {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("\ncould not write {path}: {e}"),
    }
}
