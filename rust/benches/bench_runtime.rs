//! Bench: PJRT artifact execution — compile-once cost and steady-state
//! execute latency of every AOT artifact (the L1/L2 hot path as seen from
//! Rust). Skips cleanly when artifacts are missing.

use lad::bench_support::{run, section};
use lad::runtime::{Runtime, TensorIn};
use lad::util::rng::Rng;

fn main() {
    let dir = std::env::var("LAD_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let Ok(mut rt) = Runtime::load(&dir) else {
        eprintln!("no artifacts at {dir} — run `make artifacts` first");
        return;
    };
    let mut rng = Rng::new(1);
    let meta = rt.manifest().entries["coded_grad"].meta.clone();
    let (n, q) = (meta["n"] as usize, meta["q"] as usize);
    let x = rng.gauss_vec(q);
    let z = rng.gauss_vec(n * q);
    let y = rng.gauss_vec(n);
    let a = rng.gauss_vec(n * n);

    section(&format!("PJRT linreg artifacts (N={n}, Q={q})"));
    run("coded_grad (Pallas fused eq.5)", 400.0, || {
        rt.exec_f32(
            "coded_grad",
            &[
                TensorIn::F32(&x, &[q as i64]),
                TensorIn::F32(&z, &[n as i64, q as i64]),
                TensorIn::F32(&y, &[n as i64]),
                TensorIn::F32(&a, &[n as i64, n as i64]),
            ],
        )
        .unwrap()
    });
    run("linreg_grads", 300.0, || {
        rt.exec_f32(
            "linreg_grads",
            &[
                TensorIn::F32(&x, &[q as i64]),
                TensorIn::F32(&z, &[n as i64, q as i64]),
                TensorIn::F32(&y, &[n as i64]),
            ],
        )
        .unwrap()
    });
    run("linreg_loss", 300.0, || {
        rt.exec_f32(
            "linreg_loss",
            &[
                TensorIn::F32(&x, &[q as i64]),
                TensorIn::F32(&z, &[n as i64, q as i64]),
                TensorIn::F32(&y, &[n as i64]),
            ],
        )
        .unwrap()
    });

    if rt.has("transformer_grad") {
        let tmeta = rt.manifest().entries["transformer_grad"].meta.clone();
        let p = tmeta["params"] as usize;
        let (batch, seq, vocab) =
            (tmeta["batch"] as usize, tmeta["seq"] as usize, tmeta["vocab"] as usize);
        section(&format!("PJRT transformer artifacts ({p} params)"));
        let theta = rt
            .exec_f32("transformer_init", &[TensorIn::I32(&[1], &[])])
            .unwrap()
            .remove(0);
        let windows: Vec<i32> =
            (0..batch * (seq + 1)).map(|_| rng.below(vocab) as i32).collect();
        let flops = 6.0 * p as f64 * (batch * seq) as f64;
        let r = run("transformer_grad (fwd+bwd)", 3000.0, || {
            rt.exec_f32(
                "transformer_grad",
                &[
                    TensorIn::F32(&theta, &[p as i64]),
                    TensorIn::I32(&windows, &[batch as i64, seq as i64 + 1]),
                ],
            )
            .unwrap()
        });
        println!(
            "      ≈ {:.2} GFLOP/step → {:.2} GFLOPS sustained",
            flops / 1e9,
            r.throughput(flops) / 1e9
        );
    }
    println!(
        "\nruntime stats: {} compiles ({:.2}s), {} executes ({:.2}s)",
        rt.stats.compiles, rt.stats.compile_s, rt.stats.executes, rt.stats.execute_s
    );
}
