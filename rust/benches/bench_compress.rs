//! Micro-bench: compression operators at Q=100 (paper) and Q=409k
//! (transformer gradients).

use lad::bench_support::{run, section};
use lad::compress::{Compressor, Identity, Qsgd, RandK, TopK};
use lad::util::rng::Rng;

fn main() {
    let mut rng = Rng::new(1);
    for (label, q) in [("Q=100 (paper)", 100usize), ("Q=409k (e2e)", 409_000)] {
        section(&format!("compressors, {label}"));
        let g = rng.gauss_vec(q);
        let ops: Vec<Box<dyn Compressor>> = vec![
            Box::new(Identity),
            Box::new(RandK::new((q * 3 / 10).max(1))),
            Box::new(TopK::new((q * 3 / 10).max(1))),
            Box::new(Qsgd::new(16)),
        ];
        for op in &ops {
            let mut r = Rng::new(7);
            let res = run(&op.name(), 150.0, || op.compress(&g, &mut r));
            let bits = op.compress(&g, &mut Rng::new(7)).bits;
            println!(
                "      wire = {bits} bits ({:.1}% of dense), {:.2} Melem/s",
                100.0 * bits as f64 / (32 * q) as f64,
                res.throughput(q as f64) / 1e6
            );
        }
    }
}
