//! Micro-bench: task-matrix construction, assignment draw, encoder and
//! DRACO decode — the per-iteration coding overhead of the coordinator.

use lad::bench_support::{run, section};
use lad::coding::{encode_coded_into, Assignment, DracoScheme, TaskMatrix};
use lad::util::math::Mat;
use lad::util::rng::Rng;

fn main() {
    let (n, q, d) = (100usize, 100usize, 10usize);
    let mut rng = Rng::new(1);
    let rows: Vec<Vec<f32>> = (0..n).map(|_| rng.gauss_vec(q)).collect();
    let grads = Mat::from_rows(&rows);

    section("coding layer, N=100 Q=100");
    run("task_matrix_cyclic(d=10)", 50.0, || TaskMatrix::cyclic(n, d));
    run("assignment_draw", 50.0, || {
        let mut r = Rng::new(2);
        Assignment::draw(n, &mut r)
    });

    let s = TaskMatrix::cyclic(n, d);
    let assign = Assignment::draw(n, &mut rng);
    let mut out = vec![0.0f32; q];
    run("encode_one_device(d=10)", 50.0, || {
        encode_coded_into(&grads, s.row(assign.tasks[0]), &assign, &mut out)
    });
    run("encode_all_devices(d=10)", 150.0, || {
        for i in 0..n {
            encode_coded_into(&grads, s.row(assign.tasks[i]), &assign, &mut out);
        }
    });

    section("DRACO (r=41, N=100)");
    let scheme = DracoScheme::new(n, 41);
    let msgs: Vec<Vec<f32>> = (0..n).map(|i| scheme.honest_message(i, &grads)).collect();
    run("honest_message", 100.0, || scheme.honest_message(0, &grads));
    run("majority_decode", 200.0, || scheme.decode(&msgs, 1e-3).unwrap());
}
