//! Regenerates Fig. 2 (error term vs δ) and Fig. 3 (error term vs d) and
//! prints the series the paper plots, plus the closed-form evaluation cost.

use lad::bench_support::{run, section};
use lad::experiments::{fig2, fig3};

fn main() {
    section("Fig. 2 — error term vs delta (N=100, H=65, d=5, kappa=1.5, beta=1)");
    let out2 = fig2::run(&fig2::Fig2Params::default());
    let s = &out2.series[0];
    println!("  delta : eps(eq.33)");
    for i in (0..s.x.len()).step_by(5) {
        println!("  {:>5.2} : {:.4e}", s.x[i], s.y[i]);
    }

    section("Fig. 3 — error term vs d (N=100, H=65, delta=0.5)");
    let out3 = fig3::run(&fig3::Fig3Params::default());
    let (com, lad, base) = (&out3.series[0], &out3.series[1], &out3.series[2]);
    println!("  d  : eps_comlad    eps_lad(eq.35)  baseline(eq.36)");
    for &d in &[1usize, 2, 3, 5, 10, 20, 41, 99] {
        let i = d - 1;
        println!(
            "  {:>2} : {:.4e}    {:.4e}      {:.4e}{}",
            d,
            com.y[i],
            lad.y[i],
            base.y[i],
            if lad.y[i] <= base.y[i] { "   <- LAD wins" } else { "" }
        );
    }

    section("evaluation cost");
    run("fig2 full sweep (41 deltas)", 50.0, || fig2::run(&fig2::Fig2Params::default()));
    run("fig3 full sweep (99 ds)", 50.0, || fig3::run(&fig3::Fig3Params::default()));
}
