//! Integration: DRACO vs LAD — the compute/robustness trade-off of Fig. 4.

use lad::attack::SignFlip;
use lad::config::TrainConfig;
use lad::data::linreg::LinRegDataset;
use lad::experiments::common::{run_variant, Variant};
use lad::grad::NativeLinReg;
use lad::server::trainer::DracoTrainer;
use lad::util::rng::Rng;

fn cfg() -> TrainConfig {
    let mut cfg = TrainConfig::default();
    cfg.n_devices = 30;
    cfg.n_honest = 24;
    cfg.dim = 30;
    cfg.iters = 400;
    cfg.lr = 8e-5;
    cfg.sigma_h = 0.3;
    cfg.log_every = 100;
    cfg
}

#[test]
fn draco_beats_lad_beats_plain_under_attack() {
    let cfg = cfg();
    let mut rng = Rng::new(61);
    let ds = LinRegDataset::generate(cfg.n_devices, cfg.dim, cfg.sigma_h, &mut rng);
    let mut plain = cfg.clone();
    plain.d = 1;
    let mut lad = cfg.clone();
    lad.d = 10;
    let t_plain =
        run_variant(&ds, &Variant { label: "cwtm".into(), cfg: plain, draco_r: None }, 62)
            .unwrap();
    let t_lad =
        run_variant(&ds, &Variant { label: "lad".into(), cfg: lad, draco_r: None }, 62).unwrap();
    let t_draco = run_variant(
        &ds,
        &Variant { label: "draco".into(), cfg: cfg.clone(), draco_r: Some(13) },
        62,
    )
    .unwrap();
    assert!(t_lad.final_loss <= t_plain.final_loss * 1.02, "lad !<= plain");
    assert!(t_draco.final_loss <= t_lad.final_loss * 1.05, "draco !<= lad");
    assert_eq!(t_draco.anomalies, 0);
}

#[test]
fn draco_decode_failure_is_counted_not_fatal() {
    // overwhelm one group: more Byzantine than the scheme tolerates, with
    // non-colluding lies so no majority forms -> anomalies, no panic
    let mut cfg = cfg();
    cfg.n_devices = 12;
    cfg.n_honest = 7; // 5 byz, all in the last group of r=4... groups of 4
    cfg.dim = 8;
    cfg.iters = 20;
    let mut rng = Rng::new(71);
    let ds = LinRegDataset::generate(cfg.n_devices, cfg.dim, cfg.sigma_h, &mut rng);
    let attack = lad::attack::GaussianNoise { std: 1e4 };
    let trainer = DracoTrainer { cfg: &cfg, attack: &attack, r: 3 };
    let mut oracle = NativeLinReg::new(ds);
    let mut x0 = vec![0.0; cfg.dim];
    let tr = trainer.run(&mut oracle, &mut x0, "draco-broken", &mut Rng::new(72)).unwrap();
    assert!(tr.anomalies > 0, "expected decode failures");
}

#[test]
fn draco_compute_load_vs_lad() {
    // the paper's headline trade-off: LAD d=10 ≈ DRACO quality at a
    // fraction of the compute. Verify the load accounting.
    let scheme = lad::coding::DracoScheme::new(100, 41);
    let draco_load: usize = (0..100).map(|i| scheme.load(i)).sum();
    let lad_load = 100 * 10; // d = 10
    assert!(lad_load * 2 < draco_load * 1, "lad load {lad_load} vs draco {draco_load}");
}

#[test]
fn draco_exactness_zero_heterogeneity_sensitivity() {
    // DRACO's final loss is independent of σ_H's effect on robustness
    // (it always recovers the exact gradient) — the curves differ only
    // through the dataset itself.
    let mut c = cfg();
    c.iters = 200;
    let flip = SignFlip { coeff: -2.0 };
    for sigma in [0.0, 0.5] {
        let mut rng = Rng::new(81);
        let ds = LinRegDataset::generate(c.n_devices, c.dim, sigma, &mut rng);
        // run draco and exact gradient descent side by side
        let trainer = DracoTrainer { cfg: &c, attack: &flip, r: 13 };
        let mut oracle = NativeLinReg::new(ds.clone());
        let mut x0 = vec![0.0; c.dim];
        let tr = trainer.run(&mut oracle, &mut x0, "draco", &mut Rng::new(82)).unwrap();
        // exact GD with update μ = ∇F/N
        let mut x = vec![0.0f32; c.dim];
        for _ in 0..c.iters {
            let g = ds.full_grad(&x);
            for (xi, gi) in x.iter_mut().zip(&g) {
                *xi -= (c.lr / c.n_devices as f64) as f32 * gi;
            }
        }
        let gd_loss = ds.loss(&x);
        let rel = (tr.final_loss - gd_loss).abs() / gd_loss.max(1e-9);
        assert!(rel < 1e-4, "σ={sigma}: draco {} vs gd {}", tr.final_loss, gd_loss);
    }
}
