//! Loopback integration tests for the multi-node transport: a leader plus
//! n workers over real TCP (and UDS) sockets must produce a `TrainTrace`
//! bit-identical to `Trainer::run`'s central fast path — for LAD
//! (Identity) and Com-LAD (QSGD, device-side compression) — and a stalled
//! worker must not hang an iteration once a gather deadline is set.

use lad::aggregation::{from_config, Cwtm};
use lad::attack::SignFlip;
use lad::compress::{Compressor, Identity, Qsgd};
use lad::config::{AggregatorKind, CompressionKind, TrainConfig};
use lad::data::linreg::LinRegDataset;
use lad::grad::NativeLinReg;
use lad::net::transport::{connect, ChannelTransport, NetListener, Transport};
use lad::net::wire::{Msg, Payload, WIRE_VERSION};
use lad::net::{run_worker, run_worker_opts, Leader, LeaderOpts, WorkerOpts, MISS_RETIRE_STREAK};
use lad::server::cluster::{
    run_cluster_churn, run_cluster_in, run_cluster_kill_resume, ChurnPlan, ClusterOpts,
};
use lad::server::metrics::TrainTrace;
use lad::server::trainer::Trainer;
use lad::server::Checkpoint;
use lad::util::parallel::Pool;
use lad::util::rng::Rng;
use std::time::Duration;

fn cfg(n: usize, h: usize, d: usize, compression: CompressionKind) -> TrainConfig {
    let mut cfg = TrainConfig::default();
    cfg.n_devices = n;
    cfg.n_honest = h;
    cfg.d = d;
    cfg.dim = 10;
    cfg.iters = 40;
    cfg.lr = 8e-5;
    cfg.sigma_h = 0.3;
    cfg.log_every = 10;
    cfg.compression = compression;
    cfg
}

fn central(
    cfg: &TrainConfig,
    ds: &LinRegDataset,
    comp: &dyn Compressor,
    seed: u64,
) -> (TrainTrace, Vec<f32>) {
    let cwtm = Cwtm::new(0.1);
    let flip = SignFlip { coeff: -2.0 };
    let mut oracle = NativeLinReg::new(ds.clone());
    let mut x = vec![0.0f32; cfg.dim];
    let tr = Trainer::new(cfg, &cwtm, &flip, comp)
        .run(&mut oracle, &mut x, "central", &mut Rng::new(seed))
        .unwrap();
    (tr, x)
}

/// Leader + n socket workers; workers receive the dataset over the wire
/// and compress their own uplinks (device-side Com-LAD).
fn net_loopback(
    cfg: &TrainConfig,
    ds: &LinRegDataset,
    comp: &dyn Compressor,
    seed: u64,
    bind_addr: &str,
) -> (TrainTrace, Vec<f32>) {
    let listener = NetListener::bind(bind_addr).unwrap();
    let addr = listener.local_addr().unwrap();
    let n = cfg.n_devices;
    let mut workers = Vec::with_capacity(n);
    for i in 0..n {
        let addr = addr.clone();
        workers.push(std::thread::spawn(move || {
            let link = connect(&addr).unwrap();
            run_worker(link, i, None, None).unwrap()
        }));
    }
    let links: Vec<Box<dyn Transport>> = (0..n).map(|_| listener.accept().unwrap()).collect();
    let cwtm = Cwtm::new(0.1);
    let flip = SignFlip { coeff: -2.0 };
    let leader = Leader {
        cfg,
        ds,
        agg: &cwtm,
        attack: &flip,
        comp,
        opts: LeaderOpts { gather_deadline: None, device_compression: true, ..Default::default() },
        pool: Pool::serial(),
        send_dataset: true,
    };
    let mut x = vec![0.0f32; cfg.dim];
    let tr = leader.run(links, &mut x, "net", &mut Rng::new(seed)).unwrap();
    for w in workers {
        let report = w.join().unwrap();
        assert_eq!(report.iters, cfg.iters, "worker served every iteration");
        assert!(report.up_bytes > 0 && report.down_bytes > 0);
    }
    (tr, x)
}

fn assert_trace_identical(net: &TrainTrace, central: &TrainTrace) {
    assert_eq!(net.iters, central.iters, "sample grid diverged");
    assert_eq!(net.loss, central.loss, "loss trace diverged");
    assert_eq!(net.grad_update_norm, central.grad_update_norm, "update norms diverged");
    assert_eq!(net.bits, central.bits, "bit accounting diverged");
    assert_eq!(net.final_loss, central.final_loss, "final loss diverged");
    assert_eq!(net.anomalies, 0);
}

#[test]
fn tcp_identity_matches_central_and_measures_wire_bytes() {
    let c = cfg(8, 6, 3, CompressionKind::None);
    let mut rng = Rng::new(601);
    let ds = LinRegDataset::generate(c.n_devices, c.dim, c.sigma_h, &mut rng);
    let (tn, xn) = net_loopback(&c, &ds, &Identity, 602, "tcp://127.0.0.1:0");
    let (tc, xc) = central(&c, &ds, &Identity, 602);
    assert_eq!(xn, xc, "model diverged between TCP and central paths");
    assert_trace_identical(&tn, &tc);
    // Identity ships every f32 densely: the measured uplink bytes must
    // cover the analytic accounting, the excess being framing/headers only
    assert!(
        tn.wire_up_bytes >= tn.total_bits() / 8,
        "wire {}B < analytic {}b/8",
        tn.wire_up_bytes,
        tn.total_bits()
    );
    assert!(tn.wire_down_bytes > 0);
    assert_eq!(tc.wire_up_bytes, 0, "central path serializes nothing");
}

#[test]
fn tcp_qsgd_com_lad_matches_central() {
    // device-side compression: the compressed QSGD payloads are what
    // crosses the socket, and the trace still matches the fast path
    let c = cfg(8, 6, 3, CompressionKind::Qsgd { levels: 16 });
    let mut rng = Rng::new(701);
    let ds = LinRegDataset::generate(c.n_devices, c.dim, c.sigma_h, &mut rng);
    let comp = Qsgd::new(16);
    let (tn, xn) = net_loopback(&c, &ds, &comp, 702, "tcp://127.0.0.1:0");
    let (tc, xc) = central(&c, &ds, &comp, 702);
    assert_eq!(xn, xc, "model diverged between TCP and central paths");
    assert_trace_identical(&tn, &tc);
    assert!(tn.total_bits() > 0);
}

#[test]
fn tcp_ef_qsgd_device_side_matches_central() {
    // Error feedback over the wire: each worker holds its own 1-row
    // residual state and compresses residual + gradient device-side; the
    // trace must still be bit-identical to the central trainer's EF path
    // (leader-held residuals for every device), because worker i's stream
    // seed (Hello's comp_seed) and residual evolution match central row i.
    let c = cfg(8, 6, 3, CompressionKind::EfQsgd { levels: 16 });
    let mut rng = Rng::new(711);
    let ds = LinRegDataset::generate(c.n_devices, c.dim, c.sigma_h, &mut rng);
    let comp = lad::compress::from_kind(c.compression);
    let (tn, xn) = net_loopback(&c, &ds, comp.as_ref(), 712, "tcp://127.0.0.1:0");
    let (tc, xc) = central(&c, &ds, comp.as_ref(), 712);
    assert_eq!(xn, xc, "model diverged between device-side EF and central EF");
    assert_trace_identical(&tn, &tc);
    assert!(tn.total_bits() > 0);
}

#[cfg(unix)]
#[test]
fn uds_identity_matches_central() {
    let c = cfg(6, 5, 2, CompressionKind::None);
    let mut rng = Rng::new(801);
    let ds = LinRegDataset::generate(c.n_devices, c.dim, c.sigma_h, &mut rng);
    let path = std::env::temp_dir().join(format!("lad_net_cluster_{}.sock", std::process::id()));
    let addr = format!("uds:{}", path.display());
    let (tn, xn) = net_loopback(&c, &ds, &Identity, 802, &addr);
    let (tc, xc) = central(&c, &ds, &Identity, 802);
    assert_eq!(xn, xc, "model diverged between UDS and central paths");
    assert_trace_identical(&tn, &tc);
}

#[test]
fn serve_reclaims_slot_from_silent_connector() {
    // A stray connection that never sends a Join must not occupy one of
    // the N device slots: with a join deadline, Leader::serve drops it
    // and the real workers fill every slot — and the resulting trace is
    // still bit-identical to the central fast path.
    let c = cfg(4, 3, 2, CompressionKind::None);
    let mut rng = Rng::new(951);
    let ds = LinRegDataset::generate(c.n_devices, c.dim, c.sigma_h, &mut rng);
    let listener = NetListener::bind("tcp://127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    // the silent connector arrives first and holds its connection open
    // well past the join deadline
    let silent_addr = addr.clone();
    let silent = std::thread::spawn(move || {
        let link = connect(&silent_addr).unwrap();
        std::thread::sleep(Duration::from_millis(1200));
        drop(link);
    });
    std::thread::sleep(Duration::from_millis(50)); // let it connect first
    let mut workers = Vec::with_capacity(c.n_devices);
    for i in 0..c.n_devices {
        let addr = addr.clone();
        workers.push(std::thread::spawn(move || {
            let link = connect(&addr).unwrap();
            run_worker(link, i, None, None).unwrap()
        }));
    }
    let cwtm = Cwtm::new(0.1);
    let flip = SignFlip { coeff: -2.0 };
    let leader = Leader {
        cfg: &c,
        ds: &ds,
        agg: &cwtm,
        attack: &flip,
        comp: &Identity,
        opts: LeaderOpts {
            join_deadline: Some(Duration::from_millis(150)),
            device_compression: true,
            ..Default::default()
        },
        pool: Pool::serial(),
        send_dataset: true,
    };
    let mut x = vec![0.0f32; c.dim];
    let tn = leader.serve(&listener, &mut x, "serve", &mut Rng::new(952)).unwrap();
    for w in workers {
        assert_eq!(w.join().unwrap().iters, c.iters);
    }
    silent.join().unwrap();
    let (tc, xc) = central(&c, &ds, &Identity, 952);
    assert_eq!(x, xc, "model diverged between serve() and central paths");
    assert_trace_identical(&tn, &tc);
}

#[test]
fn pipelined_shared_frame_matches_per_device_encoding() {
    // The shared x-frame broadcast (pipeline: true — one encoded iterate
    // prefix per iteration, per-device assignment tails spliced on the
    // pool, staged assignment for t+1) must be indistinguishable from the
    // legacy per-device `Msg::Broadcast` encoding: same trace, same model,
    // and — because `broadcast_prefix ‖ broadcast_tail` is byte-identical
    // to `Msg::Broadcast.encode()` — the same measured wire bytes in both
    // directions.
    let c = cfg(8, 6, 3, CompressionKind::Qsgd { levels: 16 });
    let mut rng = Rng::new(971);
    let ds = LinRegDataset::generate(c.n_devices, c.dim, c.sigma_h, &mut rng);
    let comp = Qsgd::new(16);
    let run_with = |pipeline: bool| {
        let cwtm = Cwtm::new(0.1);
        let flip = SignFlip { coeff: -2.0 };
        std::thread::scope(|scope| {
            let mut links: Vec<Box<dyn Transport>> = Vec::with_capacity(c.n_devices);
            for i in 0..c.n_devices {
                let (leader_half, worker_half) = ChannelTransport::pair();
                links.push(Box::new(leader_half));
                let dsr = &ds;
                scope.spawn(move || {
                    let _ = run_worker(Box::new(worker_half), i, Some(dsr), None);
                });
            }
            let leader = Leader {
                cfg: &c,
                ds: &ds,
                agg: &cwtm,
                attack: &flip,
                comp: &comp,
                opts: LeaderOpts { pipeline, ..Default::default() },
                pool: Pool::new(4),
                send_dataset: false,
            };
            let mut x0 = vec![0.0f32; c.dim];
            let tr = leader.run(links, &mut x0, "pipeline", &mut Rng::new(972)).unwrap();
            (tr, x0)
        })
    };
    let (tp, xp) = run_with(true);
    let (ts, xs) = run_with(false);
    assert_eq!(xp, xs, "model diverged between pipelined and phase-serial paths");
    assert_eq!(tp.loss, ts.loss, "loss trace diverged");
    assert_eq!(tp.grad_update_norm, ts.grad_update_norm, "update norms diverged");
    assert_eq!(tp.bits, ts.bits, "bit accounting diverged");
    assert_eq!(tp.final_loss, ts.final_loss);
    assert_eq!(tp.anomalies, ts.anomalies);
    assert_eq!(tp.wire_down_bytes, ts.wire_down_bytes, "downlink framing diverged");
    assert_eq!(tp.wire_up_bytes, ts.wire_up_bytes, "uplink framing diverged");
    // and both legs still match the central fast path
    let (tc, xc) = central(&c, &ds, &comp, 972);
    assert_eq!(xp, xc, "model diverged from the central fast path");
    assert_trace_identical(&tp, &tc);
}

/// A worker that serves the first `serve` iterations, then stalls: keeps
/// its connection open but never uploads again (crash-Byzantine).
fn stalling_worker(mut link: Box<dyn Transport>, device: usize, serve: usize) {
    link.send(&Msg::Join { version: WIRE_VERSION, device: device as u32, digest: 0 }).unwrap();
    let (hello, _) = link.recv().unwrap();
    assert!(matches!(hello, Msg::Hello { .. }));
    let mut served = 0;
    loop {
        match link.recv() {
            Ok((Msg::Broadcast { iter, x, .. }, _)) if served < serve => {
                let payload = Payload::Dense { values: vec![0.0f32; x.len()] };
                link.send(&Msg::Upload {
                    iter,
                    device: device as u32,
                    analytic_bits: 0,
                    payload,
                })
                .unwrap();
                served += 1;
            }
            Ok((Msg::Broadcast { .. }, _)) => {} // stall: swallow silently
            Ok((Msg::Shutdown, _)) | Err(_) => break,
            Ok((other, _)) => panic!("unexpected {other:?}"),
        }
    }
}

#[test]
fn ef_residual_reset_on_retirement_is_deterministic() {
    // EF + gather deadline + a worker that stalls after 2 iterations: the
    // leader eats one anomaly per miss until MISS_RETIRE_STREAK, then
    // retires the device and zeroes its EF residual row. The semantics
    // pinned here: a mere deadline miss leaves the residual (and the
    // device's compression stream) untouched; only retirement resets it —
    // and the whole scenario is deterministic, so two runs bit-match.
    let mut c = cfg(5, 4, 2, CompressionKind::EfQsgd { levels: 16 });
    c.dim = 6;
    c.iters = 6;
    c.log_every = 2;
    let mut rng = Rng::new(911);
    let ds = LinRegDataset::generate(c.n_devices, c.dim, c.sigma_h, &mut rng);
    let comp = lad::compress::from_kind(c.compression);
    let run_once = || {
        let cwtm = Cwtm::new(0.1);
        let flip = SignFlip { coeff: -2.0 };
        std::thread::scope(|scope| {
            let mut links: Vec<Box<dyn Transport>> = Vec::with_capacity(c.n_devices);
            for i in 0..c.n_devices {
                let (leader_half, worker_half) = ChannelTransport::pair();
                links.push(Box::new(leader_half));
                let dsr = &ds;
                if i == 1 {
                    scope.spawn(move || stalling_worker(Box::new(worker_half), 1, 2));
                } else {
                    scope.spawn(move || {
                        let _ = run_worker(Box::new(worker_half), i, Some(dsr), None);
                    });
                }
            }
            let leader = Leader {
                cfg: &c,
                ds: &ds,
                agg: &cwtm,
                attack: &flip,
                comp: comp.as_ref(),
                opts: LeaderOpts {
                    gather_deadline: Some(Duration::from_millis(200)),
                    device_compression: false,
                    ..Default::default()
                },
                pool: Pool::serial(),
                send_dataset: false,
            };
            let mut x0 = vec![0.0f32; c.dim];
            let tr = leader.run(links, &mut x0, "ef-retire", &mut Rng::new(912)).unwrap();
            (tr, x0)
        })
    };
    let (t1, x1) = run_once();
    let (t2, x2) = run_once();
    assert_eq!(t1.anomalies, MISS_RETIRE_STREAK, "one anomaly per miss until retirement");
    assert_eq!(x1, x2, "EF retirement path is not deterministic");
    assert_eq!(t1.loss, t2.loss, "loss trace diverged across reruns");
    assert_eq!(t1.grad_update_norm, t2.grad_update_norm, "update norms diverged");
    assert_eq!(t1.bits, t2.bits, "bit accounting diverged");
    assert_eq!(t2.anomalies, MISS_RETIRE_STREAK);
    assert!(t1.final_loss.is_finite());
    assert_eq!(t1.iters.last().copied(), Some(c.iters - 1));
}

#[test]
fn warm_restart_is_bit_identical_to_an_uninterrupted_run() {
    // The leader-kill drill under the most stateful arm available —
    // error-feedback compression (leader-held residual mirror) plus
    // momentum-filter aggregation (per-device momentum buffers): kill at
    // iteration 17, warm-restart from the checkpoint, and the finished
    // trace, final iterate AND wire-byte totals must be bit-identical to
    // a run that was never killed (resume handshake bytes are uncounted).
    let mut c = cfg(8, 6, 3, CompressionKind::EfQsgd { levels: 16 });
    c.aggregator = AggregatorKind::MomentumFilter;
    let mut rng = Rng::new(1301);
    let ds = LinRegDataset::generate(c.n_devices, c.dim, c.sigma_h, &mut rng);
    let flip = SignFlip { coeff: -2.0 };
    let comp = lad::compress::from_kind(c.compression);
    let pool = Pool::serial();
    let agg_ref = from_config(&c);
    let mut x_ref = vec![0.0f32; c.dim];
    let t_ref = run_cluster_in(
        &c,
        &ds,
        agg_ref.as_ref(),
        &flip,
        comp.as_ref(),
        &mut x_ref,
        "elastic",
        &mut Rng::new(1302),
        &pool,
    )
    .unwrap();
    let ckpt = std::env::temp_dir().join(format!("lad_warm_restart_{}.ckpt", std::process::id()));
    let agg_kill = from_config(&c);
    let mut x_kill = vec![0.0f32; c.dim];
    let t_kill = run_cluster_kill_resume(
        &c,
        &ds,
        agg_kill.as_ref(),
        &flip,
        comp.as_ref(),
        &mut x_kill,
        "elastic",
        &mut Rng::new(1302),
        &pool,
        &ClusterOpts::default(),
        17,
        &ckpt,
    )
    .unwrap();
    let _ = std::fs::remove_file(&ckpt);
    assert_eq!(x_kill, x_ref, "final iterate diverged across the kill/restart boundary");
    assert_eq!(t_kill.loss, t_ref.loss, "loss trace diverged");
    assert_eq!(t_kill.grad_update_norm, t_ref.grad_update_norm, "update norms diverged");
    assert_eq!(t_kill.bits, t_ref.bits, "bit accounting diverged");
    assert_eq!(t_kill.iters, t_ref.iters, "sample grid diverged");
    assert_eq!(t_kill.final_loss, t_ref.final_loss);
    assert_eq!(t_kill.anomalies, t_ref.anomalies);
    assert_eq!(t_kill.wire_up_bytes, t_ref.wire_up_bytes, "uplink byte totals diverged");
    assert_eq!(t_kill.wire_down_bytes, t_ref.wire_down_bytes, "downlink byte totals diverged");
}

#[test]
fn churn_retires_the_victim_and_rejoins_a_replacement_deterministically() {
    // Device 1 departs at iteration 4, is retired after MISS_RETIRE_STREAK
    // deadline misses, and a replacement adopts the slot at iteration 7
    // with a fresh split stream seed and a zeroed EF residual. The whole
    // scenario is deterministic (two runs bit-match), the anomaly count is
    // exactly the retirement streak, and the incumbents' streams are
    // untouched — the pre-departure samples equal the no-churn run's.
    let mut c = cfg(5, 4, 2, CompressionKind::EfQsgd { levels: 16 });
    c.dim = 6;
    c.iters = 16;
    c.log_every = 4;
    let mut rng = Rng::new(1401);
    let ds = LinRegDataset::generate(c.n_devices, c.dim, c.sigma_h, &mut rng);
    let flip = SignFlip { coeff: -2.0 };
    let comp = lad::compress::from_kind(c.compression);
    let pool = Pool::serial();
    let opts = ClusterOpts {
        leader: LeaderOpts {
            gather_deadline: Some(Duration::from_millis(200)),
            ..Default::default()
        },
        ..Default::default()
    };
    let plan = ChurnPlan { victim: 1, depart_iter: 4, rejoin_iter: 7 };
    let run_once = || {
        let cwtm = Cwtm::new(0.1);
        let mut x0 = vec![0.0f32; c.dim];
        let tr = run_cluster_churn(
            &c,
            &ds,
            &cwtm,
            &flip,
            comp.as_ref(),
            &mut x0,
            "churn",
            &mut Rng::new(1402),
            &pool,
            &opts,
            plan,
        )
        .unwrap();
        (tr, x0)
    };
    let (t1, x1) = run_once();
    let (t2, x2) = run_once();
    assert_eq!(x1, x2, "churn scenario is not deterministic");
    assert_eq!(t1.loss, t2.loss, "loss trace diverged across reruns");
    assert_eq!(t1.grad_update_norm, t2.grad_update_norm);
    assert_eq!(t1.bits, t2.bits, "bit accounting diverged");
    assert_eq!(t1.anomalies, MISS_RETIRE_STREAK, "one anomaly per miss until retirement");
    assert!(t1.final_loss.is_finite());
    assert_eq!(t1.iters.last().copied(), Some(c.iters - 1));
    // pre-departure the run is the no-churn run: the t=0 sample matches
    let cwtm = Cwtm::new(0.1);
    let mut x_ref = vec![0.0f32; c.dim];
    let t_ref = run_cluster_in(
        &c,
        &ds,
        &cwtm,
        &flip,
        comp.as_ref(),
        &mut x_ref,
        "churn",
        &mut Rng::new(1402),
        &pool,
    )
    .unwrap();
    assert_eq!(t1.loss[0], t_ref.loss[0], "pre-departure sample diverged from no-churn run");
    assert_eq!(t1.grad_update_norm[0], t_ref.grad_update_norm[0]);
}

#[test]
fn tcp_failover_drill_reconnects_workers_and_matches_an_unkilled_run() {
    // The full standby-leader drill over real sockets, with device-side
    // QSGD so live worker compression streams must survive the failover:
    // leader A checkpoints every 5 iterations and halts after iteration 12
    // WITHOUT Shutdown; the standby listener is already bound, so the
    // workers' redial loops land on leader B, which warm-restarts from the
    // checkpoint. Every worker serves every iteration (exactly one
    // reconnect each), and trace + final iterate are bit-identical to a
    // never-killed reference run.
    let mut c = cfg(6, 5, 2, CompressionKind::Qsgd { levels: 16 });
    c.iters = 30;
    let mut rng = Rng::new(1501);
    let ds = LinRegDataset::generate(c.n_devices, c.dim, c.sigma_h, &mut rng);
    let comp = Qsgd::new(16);
    let n = c.n_devices;
    let ckpt_path =
        std::env::temp_dir().join(format!("lad_failover_{}.ckpt", std::process::id()));

    let serve_reference = || {
        let listener = NetListener::bind("tcp://127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let mut workers = Vec::with_capacity(n);
        for i in 0..n {
            let addr = addr.clone();
            workers.push(std::thread::spawn(move || {
                let link = connect(&addr).unwrap();
                run_worker(link, i, None, None).unwrap()
            }));
        }
        let cwtm = Cwtm::new(0.1);
        let flip = SignFlip { coeff: -2.0 };
        let leader = Leader {
            cfg: &c,
            ds: &ds,
            agg: &cwtm,
            attack: &flip,
            comp: &comp,
            opts: LeaderOpts { device_compression: true, ..Default::default() },
            pool: Pool::serial(),
            send_dataset: true,
        };
        let mut x0 = vec![0.0f32; c.dim];
        let tr = leader.serve(&listener, &mut x0, "failover", &mut Rng::new(1502)).unwrap();
        for w in workers {
            w.join().unwrap();
        }
        (tr, x0)
    };
    let (t_ref, x_ref) = serve_reference();

    // the standby listener exists BEFORE the kill, so redials can land
    let listener_a = NetListener::bind("tcp://127.0.0.1:0").unwrap();
    let listener_b = NetListener::bind("tcp://127.0.0.1:0").unwrap();
    let addr_a = listener_a.local_addr().unwrap();
    let addr_b = listener_b.local_addr().unwrap();
    let mut workers = Vec::with_capacity(n);
    for i in 0..n {
        let addr_a = addr_a.clone();
        let addr_b = addr_b.clone();
        workers.push(std::thread::spawn(move || {
            let link = connect(&addr_a).unwrap();
            let wopts = WorkerOpts {
                reconnect_addr: Some(addr_b),
                reconnect_attempts: 60,
                reconnect_backoff: Duration::from_millis(50),
                ..Default::default()
            };
            run_worker_opts(link, i, None, None, &wopts).unwrap()
        }));
    }
    let cwtm = Cwtm::new(0.1);
    let flip = SignFlip { coeff: -2.0 };
    let opts_a = LeaderOpts {
        device_compression: true,
        checkpoint_every: 5,
        checkpoint_path: Some(ckpt_path.clone()),
        halt_after: Some(12),
        ..Default::default()
    };
    let leader_a = Leader {
        cfg: &c,
        ds: &ds,
        agg: &cwtm,
        attack: &flip,
        comp: &comp,
        opts: opts_a,
        pool: Pool::serial(),
        send_dataset: true,
    };
    let mut x0 = vec![0.0f32; c.dim];
    let err = leader_a.serve(&listener_a, &mut x0, "failover", &mut Rng::new(1502)).unwrap_err();
    assert!(err.to_string().contains("halt-after drill"), "unexpected error: {err:#}");
    drop(listener_a);

    let ckpt = Checkpoint::load(&ckpt_path).unwrap();
    assert_eq!(ckpt.iter, 13, "checkpoint cut sits after the halt iteration");
    let leader_b = Leader {
        cfg: &c,
        ds: &ds,
        agg: &cwtm,
        attack: &flip,
        comp: &comp,
        opts: LeaderOpts { device_compression: true, ..Default::default() },
        pool: Pool::serial(),
        send_dataset: true,
    };
    let mut x1 = vec![0.0f32; c.dim];
    let t_drill = leader_b.serve_resume(&listener_b, &ckpt, &mut x1, "failover").unwrap();
    let _ = std::fs::remove_file(&ckpt_path);
    for w in workers {
        let report = w.join().unwrap();
        assert_eq!(report.iters, c.iters, "worker missed iterations across the failover");
        assert_eq!(report.reconnects, 1, "worker should have redialed exactly once");
    }
    assert_eq!(x1, x_ref, "final iterate diverged across the leader failover");
    assert_eq!(t_drill.loss, t_ref.loss, "loss trace diverged");
    assert_eq!(t_drill.grad_update_norm, t_ref.grad_update_norm);
    assert_eq!(t_drill.bits, t_ref.bits, "bit accounting diverged");
    assert_eq!(t_drill.final_loss, t_ref.final_loss);
    assert_eq!(t_drill.wire_up_bytes, t_ref.wire_up_bytes, "uplink byte totals diverged");
    assert_eq!(t_drill.wire_down_bytes, t_ref.wire_down_bytes, "downlink byte totals diverged");
}

#[test]
fn rotating_byzantine_identities_match_the_central_trainer() {
    // Per-iteration Byzantine role rotation over the wire (the Broadcast
    // role bit), leader-side compression: the message-passing path must
    // stay bit-identical to the central trainer with rotate_byzantine on,
    // because both consume the run RNG in the same fixed order
    // (draw, byz_set, craft per iteration).
    let c = cfg(8, 6, 3, CompressionKind::Qsgd { levels: 16 });
    let mut rng = Rng::new(1601);
    let ds = LinRegDataset::generate(c.n_devices, c.dim, c.sigma_h, &mut rng);
    let comp = Qsgd::new(16);
    let cwtm = Cwtm::new(0.1);
    let flip = SignFlip { coeff: -2.0 };
    let (tn, xn) = std::thread::scope(|scope| {
        let mut links: Vec<Box<dyn Transport>> = Vec::with_capacity(c.n_devices);
        for i in 0..c.n_devices {
            let (leader_half, worker_half) = ChannelTransport::pair();
            links.push(Box::new(leader_half));
            let dsr = &ds;
            scope.spawn(move || {
                let _ = run_worker(Box::new(worker_half), i, Some(dsr), None);
            });
        }
        let leader = Leader {
            cfg: &c,
            ds: &ds,
            agg: &cwtm,
            attack: &flip,
            comp: &comp,
            opts: LeaderOpts { rotate_byzantine: true, ..Default::default() },
            pool: Pool::serial(),
            send_dataset: false,
        };
        let mut x0 = vec![0.0f32; c.dim];
        let tr = leader.run(links, &mut x0, "rotate", &mut Rng::new(1602)).unwrap();
        (tr, x0)
    });
    let mut oracle = NativeLinReg::new(ds.clone());
    let mut xc = vec![0.0f32; c.dim];
    let mut trainer = Trainer::new(&c, &cwtm, &flip, &comp);
    trainer.rotate_byzantine = true;
    let tc = trainer.run(&mut oracle, &mut xc, "rotate", &mut Rng::new(1602)).unwrap();
    assert_eq!(xn, xc, "model diverged between rotating net path and central trainer");
    assert_trace_identical(&tn, &tc);
}

#[test]
fn rotation_composes_with_worker_churn() {
    // Rotating roles + a churned slot: the rejoined replacement picks up
    // whatever role the rotation assigns it each iteration, and the whole
    // composition stays deterministic across reruns.
    let mut c = cfg(5, 4, 2, CompressionKind::Qsgd { levels: 16 });
    c.dim = 6;
    c.iters = 14;
    c.log_every = 4;
    let mut rng = Rng::new(1701);
    let ds = LinRegDataset::generate(c.n_devices, c.dim, c.sigma_h, &mut rng);
    let flip = SignFlip { coeff: -2.0 };
    let comp = Qsgd::new(16);
    let pool = Pool::serial();
    let opts = ClusterOpts {
        leader: LeaderOpts {
            gather_deadline: Some(Duration::from_millis(200)),
            rotate_byzantine: true,
            ..Default::default()
        },
        ..Default::default()
    };
    let plan = ChurnPlan { victim: 2, depart_iter: 3, rejoin_iter: 6 };
    let run_once = || {
        let cwtm = Cwtm::new(0.1);
        let mut x0 = vec![0.0f32; c.dim];
        let tr = run_cluster_churn(
            &c,
            &ds,
            &cwtm,
            &flip,
            &comp,
            &mut x0,
            "rotate-churn",
            &mut Rng::new(1702),
            &pool,
            &opts,
            plan,
        )
        .unwrap();
        (tr, x0)
    };
    let (t1, x1) = run_once();
    let (t2, x2) = run_once();
    assert_eq!(x1, x2, "rotation + churn is not deterministic");
    assert_eq!(t1.loss, t2.loss);
    assert_eq!(t1.bits, t2.bits);
    assert_eq!(t1.anomalies, MISS_RETIRE_STREAK);
    assert!(t1.final_loss.is_finite());
}

#[test]
fn gather_deadline_survives_a_stalled_worker() {
    let mut c = cfg(5, 4, 2, CompressionKind::None);
    c.dim = 6;
    c.iters = 6;
    c.log_every = 2;
    let mut rng = Rng::new(901);
    let ds = LinRegDataset::generate(c.n_devices, c.dim, c.sigma_h, &mut rng);
    let cwtm = Cwtm::new(0.1);
    let flip = SignFlip { coeff: -2.0 };
    let (tr, x) = std::thread::scope(|scope| {
        let mut links: Vec<Box<dyn Transport>> = Vec::with_capacity(c.n_devices);
        for i in 0..c.n_devices {
            let (leader_half, worker_half) = ChannelTransport::pair();
            links.push(Box::new(leader_half));
            let dsr = &ds;
            if i == 1 {
                scope.spawn(move || stalling_worker(Box::new(worker_half), 1, 2));
            } else {
                scope.spawn(move || {
                    let _ = run_worker(Box::new(worker_half), i, Some(dsr), None);
                });
            }
        }
        let leader = Leader {
            cfg: &c,
            ds: &ds,
            agg: &cwtm,
            attack: &flip,
            comp: &Identity,
            opts: LeaderOpts {
                gather_deadline: Some(Duration::from_millis(200)),
                device_compression: false,
                ..Default::default()
            },
            pool: Pool::serial(),
            send_dataset: false,
        };
        let mut x0 = vec![0.0f32; c.dim];
        let tr = leader.run(links, &mut x0, "deadline", &mut Rng::new(902)).unwrap();
        (tr, x0)
    });
    // device 1 answered iterations 0 and 1, then stalled: the leader eats
    // one timeout per miss until the retire streak, then stops waiting on
    // (and broadcasting to) the dead device entirely — a permanent stall
    // costs a bounded number of timeouts, not one per remaining iteration
    assert_eq!(tr.anomalies, MISS_RETIRE_STREAK, "one anomaly per miss until retirement");
    assert!(tr.final_loss.is_finite());
    assert!(x.iter().all(|v| v.is_finite()));
    // the run still records its full sample grid
    assert_eq!(tr.iters.last().copied(), Some(c.iters - 1));
}
