//! The parallel execution engine's end-to-end contract: `threads = 1` and
//! `threads = 8` must produce **bit-identical** `TrainTrace`s for LAD and
//! Com-LAD, across aggregators (including the row-parallel O(N²Q) rules)
//! and attacks. Randomness is pre-split per device (`Rng::split`), never
//! shared across threads, so the schedule cannot leak into the math.
//!
//! Problem sizes are chosen above every internal parallelism gate
//! (oracle: N·Q ≥ 4096; pairwise rules: N²·Q ≥ 2¹⁶; compression:
//! N·Q ≥ 4096) so the multi-threaded paths genuinely execute.

use lad::config::{AggregatorKind, AttackKind, CompressionKind, TrainConfig};
use lad::data::linreg::LinRegDataset;
use lad::experiments::common::{run_figure_par, run_variant, Variant};
use lad::server::TrainTrace;
use lad::util::parallel::Parallelism;
use lad::util::rng::Rng;

fn base_cfg() -> TrainConfig {
    let mut cfg = TrainConfig::default();
    cfg.n_devices = 64;
    cfg.n_honest = 48;
    cfg.d = 8;
    cfg.dim = 128;
    cfg.iters = 40;
    cfg.lr = 1e-6;
    cfg.sigma_h = 0.3;
    cfg.log_every = 10;
    cfg
}

fn run_with_threads(mut cfg: TrainConfig, threads: usize, seed: u64) -> TrainTrace {
    cfg.threads = threads;
    let mut rng = Rng::new(seed);
    let ds = LinRegDataset::generate(cfg.n_devices, cfg.dim, cfg.sigma_h, &mut rng);
    run_variant(&ds, &Variant { label: format!("{threads}t"), cfg, draco_r: None }, seed ^ 0xD)
        .unwrap()
}

fn assert_traces_identical(a: &TrainTrace, b: &TrainTrace, what: &str) {
    assert_eq!(a.iters, b.iters, "{what}: sampled iterations differ");
    assert_eq!(a.loss, b.loss, "{what}: loss trace differs");
    assert_eq!(
        a.grad_update_norm, b.grad_update_norm,
        "{what}: update-norm trace differs"
    );
    assert_eq!(a.bits, b.bits, "{what}: bit accounting differs");
    assert_eq!(a.final_loss, b.final_loss, "{what}: final loss differs");
}

#[test]
fn lad_traces_bit_identical_across_thread_counts() {
    // LAD (no compression), two aggregators incl. the row-parallel rules
    for (agg, nnm) in [
        (AggregatorKind::Cwtm, true),  // CWTM-NNM: parallel mixing pass
        (AggregatorKind::MultiKrum, false), // parallel pairwise scores
    ] {
        let mut cfg = base_cfg();
        cfg.aggregator = agg;
        cfg.nnm = nnm;
        cfg.attack = AttackKind::SignFlip { coeff: -2.0 };
        let serial = run_with_threads(cfg.clone(), 1, 11);
        for threads in [2usize, 8] {
            let par = run_with_threads(cfg.clone(), threads, 11);
            assert_traces_identical(
                &serial,
                &par,
                &format!("lad/{agg:?}/nnm={nnm}/threads={threads}"),
            );
        }
    }
}

#[test]
fn com_lad_traces_bit_identical_across_thread_counts() {
    // Com-LAD: stochastic rand-K compression exercises the pre-split
    // per-device RNG streams — the hardest part of the contract
    for attack in [AttackKind::SignFlip { coeff: -2.0 }, AttackKind::Alie] {
        let mut cfg = base_cfg();
        cfg.aggregator = AggregatorKind::Cwtm;
        cfg.nnm = true;
        cfg.attack = attack;
        cfg.compression = CompressionKind::RandK { k: 32 };
        let serial = run_with_threads(cfg.clone(), 1, 13);
        let par = run_with_threads(cfg.clone(), 8, 13);
        assert_traces_identical(&serial, &par, &format!("com-lad/{attack:?}"));
        // compression actually happened: rand-K wire size < dense
        assert!(serial.total_bits() < (cfg.n_devices * cfg.dim * 32 * cfg.iters) as u64);
    }
}

#[test]
fn com_lad_qsgd_trace_bit_identical_across_thread_counts() {
    let mut cfg = base_cfg();
    cfg.aggregator = AggregatorKind::MultiKrum;
    cfg.attack = AttackKind::SignFlip { coeff: -2.0 };
    cfg.compression = CompressionKind::Qsgd { levels: 16 };
    let serial = run_with_threads(cfg.clone(), 1, 17);
    let par = run_with_threads(cfg, 8, 17);
    assert_traces_identical(&serial, &par, "com-lad/qsgd/multi-krum");
}

#[test]
fn variant_fanout_matches_serial_sweep() {
    // driver-level parallelism: the same variant family run serially and
    // with the thread fan-out must produce identical traces, in order
    let mk = |label: &str, d: usize, agg: AggregatorKind| {
        let mut cfg = base_cfg();
        cfg.d = d;
        cfg.aggregator = agg;
        Variant { label: label.into(), cfg, draco_r: None }
    };
    let variants = vec![
        mk("cwtm-d1", 1, AggregatorKind::Cwtm),
        mk("cwtm-d8", 8, AggregatorKind::Cwtm),
        mk("median-d8", 8, AggregatorKind::Median),
        mk("faba-d8", 8, AggregatorKind::Faba),
    ];
    let serial =
        run_figure_par(64, 128, 0.3, &variants, 21, 22, Parallelism::serial()).unwrap();
    let fanned = run_figure_par(64, 128, 0.3, &variants, 21, 22, Parallelism::new(4)).unwrap();
    assert_eq!(serial.len(), fanned.len());
    for (a, b) in serial.iter().zip(&fanned) {
        assert_eq!(a.label, b.label, "fan-out reordered variants");
        assert_traces_identical(a, b, &a.label);
    }
}
