//! Property tests on the compression operators (Definition 2) and the
//! error-feedback memory stage wrapped around them (arXiv 2310.09804).

use lad::compress::{
    compress_batch_ef, measure_bias_delta, Compressor, EfState, Identity, Qsgd, RandK, TopK,
};
use lad::proptest_lite::{ensure, forall, gen};
use lad::util::math::axpy;
use lad::util::parallel::Pool;
use lad::util::rng::Rng;

/// Unbiasedness (eq. 9) for the unbiased operators, across shapes/scales.
#[test]
fn prop_unbiased_operators_are_unbiased() {
    forall(
        12,
        0xB1,
        |rng: &mut Rng| {
            let q = gen::usize_in(rng, 4, 64);
            let k = gen::usize_in(rng, 1, q);
            let scale = 10f32.powi(gen::usize_in(rng, 0, 3) as i32 - 1);
            let g = gen::vec_f32(rng, q, scale);
            let seed = rng.next_u64();
            (g, k, seed)
        },
        |(g, k, seed)| {
            let mut rng = Rng::new(*seed);
            let ops: Vec<Box<dyn Compressor>> =
                vec![Box::new(Identity), Box::new(RandK::new(*k)), Box::new(Qsgd::new(8))];
            for op in ops {
                let (bias, _) = measure_bias_delta(op.as_ref(), g, 8_000, &mut rng);
                ensure(bias < 0.05, || format!("{}: bias {bias}", op.name()))?;
            }
            Ok(())
        },
    );
}

/// δ bound (eq. 10): measured relative error ≤ theoretical δ (+ slack).
#[test]
fn prop_delta_bound_holds() {
    forall(
        12,
        0xB2,
        |rng: &mut Rng| {
            let q = gen::usize_in(rng, 8, 64);
            let k = gen::usize_in(rng, 1, q);
            let g = gen::vec_f32(rng, q, 3.0);
            let seed = rng.next_u64();
            (g, k, seed)
        },
        |(g, k, seed)| {
            let mut rng = Rng::new(*seed);
            let q = g.len();
            for op in [RandK::new(*k)] {
                let bound = op.delta(q).unwrap();
                let (_, d) = measure_bias_delta(&op, g, 8_000, &mut rng);
                ensure(d <= bound * 1.25 + 0.05, || {
                    format!("{}: δ̂ {d} > bound {bound}", op.name())
                })?;
            }
            let qs = Qsgd::new(4);
            let bound = qs.delta(q).unwrap();
            let (_, d) = measure_bias_delta(&qs, g, 8_000, &mut rng);
            ensure(d <= bound * 1.25 + 0.05, || format!("qsgd: δ̂ {d} > bound {bound}"))
        },
    );
}

/// Wire size is monotone in K and never exceeds dense f32.
#[test]
fn prop_bits_monotone_and_bounded() {
    forall(
        40,
        0xB3,
        |rng: &mut Rng| {
            let q = gen::usize_in(rng, 8, 128);
            let g = gen::vec_f32(rng, q, 1.0);
            let seed = rng.next_u64();
            (g, seed)
        },
        |(g, seed)| {
            let mut rng = Rng::new(*seed);
            let q = g.len();
            let mut prev = 0usize;
            for k in [1usize, q / 4 + 1, q / 2 + 1] {
                let c = RandK::new(k.min(q)).compress(g, &mut rng);
                ensure(c.bits >= prev, || format!("bits not monotone at k={k}"))?;
                prev = c.bits;
            }
            let dense = Identity.compress(g, &mut rng);
            let sparse = RandK::new((q / 4).max(1)).compress(g, &mut rng);
            ensure(sparse.bits < dense.bits, || {
                format!("rand-k ({}) not cheaper than dense ({})", sparse.bits, dense.bits)
            })
        },
    );
}

/// Support size: rand-K and top-K keep exactly K nonzeros (for generic g).
#[test]
fn prop_sparsifiers_support_size() {
    forall(
        40,
        0xB4,
        |rng: &mut Rng| {
            let q = gen::usize_in(rng, 4, 64);
            let k = gen::usize_in(rng, 1, q);
            // strictly nonzero entries so support is exactly K
            let g: Vec<f32> = (0..q)
                .map(|_| (rng.f32() + 0.1) * if rng.bernoulli(0.5) { 1.0 } else { -1.0 })
                .collect();
            let seed = rng.next_u64();
            (g, k, seed)
        },
        |(g, k, seed)| {
            let mut rng = Rng::new(*seed);
            for op in [&RandK::new(*k) as &dyn Compressor, &TopK::new(*k)] {
                let c = op.compress(g, &mut rng);
                let nnz = c.vec.iter().filter(|&&x| x != 0.0).count();
                ensure(nnz == *k, || format!("{}: nnz {nnz} != k {k}", op.name()))?;
            }
            Ok(())
        },
    );
}

/// EF decomposition is exact by construction, for every base operator
/// (rand-K, top-K, QSGD) and across consecutive steps: after a step, the
/// stored residual is *bitwise* the elementwise difference between the EF
/// input (residual_in + gradient, formed with `axpy` in the same op order
/// as `EfState::input`) and the transmitted message — and on every
/// coordinate a sparsifier zeroes, the residual keeps the input bit-exactly.
#[test]
fn prop_ef_residual_decomposition_is_construction_exact() {
    forall(
        24,
        0xE1,
        |rng: &mut Rng| {
            let q = gen::usize_in(rng, 4, 64);
            let k = gen::usize_in(rng, 1, q);
            let levels = gen::usize_in(rng, 2, 16) as u32;
            let g0 = gen::vec_f32(rng, q, 3.0);
            let g1 = gen::vec_f32(rng, q, 3.0);
            let seed = rng.next_u64();
            (g0, g1, k, levels, seed)
        },
        |(g0, g1, k, levels, seed)| {
            let ops: Vec<Box<dyn Compressor>> = vec![
                Box::new(RandK::new(*k)),
                Box::new(TopK::new(*k)),
                Box::new(Qsgd::new(*levels)),
            ];
            for op in ops {
                let mut st = EfState::new(1, g0.len());
                let mut rng = Rng::new(*seed);
                for g in [g0, g1] {
                    // recompute the EF input exactly as EfState::input does
                    let mut a = st.residual(0).to_vec();
                    axpy(1.0, g, &mut a);
                    let c = st.step(0, g, op.as_ref(), &mut rng);
                    for j in 0..g.len() {
                        let want = a[j] - c.vec[j];
                        ensure(st.residual(0)[j].to_bits() == want.to_bits(), || {
                            format!(
                                "{}: coord {j}: residual {} != input - transmitted {}",
                                op.name(),
                                st.residual(0)[j],
                                want
                            )
                        })?;
                        if c.vec[j] == 0.0 {
                            ensure(st.residual(0)[j].to_bits() == a[j].to_bits(), || {
                                format!(
                                    "{}: dropped coord {j} lost input mass bitwise",
                                    op.name()
                                )
                            })?;
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

/// Under the Identity operator the EF stage is inert: the residual stays
/// exactly 0.0 on every coordinate over any gradient sequence, and the
/// transmitted message is the gradient itself.
#[test]
fn prop_ef_identity_residual_stays_zero() {
    forall(
        24,
        0xE2,
        |rng: &mut Rng| {
            let q = gen::usize_in(rng, 2, 48);
            let steps = gen::usize_in(rng, 1, 6);
            let gs: Vec<Vec<f32>> =
                (0..steps).map(|_| gen::vec_f32(rng, q, 50.0)).collect();
            gs
        },
        |gs| {
            let mut st = EfState::new(1, gs[0].len());
            let mut rng = Rng::new(0);
            for g in gs {
                let c = st.step(0, g, &Identity, &mut rng);
                ensure(c.vec == *g, || "identity EF altered the gradient".into())?;
                ensure(st.residual(0).iter().all(|e| e.to_bits() == 0), || {
                    format!("residual drifted off zero: {:?}", st.residual(0))
                })?;
            }
            Ok(())
        },
    );
}

/// The batched EF uplink is invariant to the pool width and bit-identical
/// to the per-device `EfState::step` path — messages AND carried residuals
/// — because each device owns its pre-split stream and its residual row.
#[test]
fn prop_ef_batch_thread_count_invariant() {
    forall(
        10,
        0xE3,
        |rng: &mut Rng| {
            let n = gen::usize_in(rng, 2, 8);
            let q = gen::usize_in(rng, 8, 48);
            let k = gen::usize_in(rng, 1, q);
            let fam = gen::vec_family(rng, n, q, 2.0);
            let seed = rng.next_u64();
            (fam, k, seed)
        },
        |(fam, k, seed)| {
            let n = fam.len();
            let msgs: Vec<&[f32]> = fam.iter().map(|m| m.as_slice()).collect();
            let comp = RandK::new(*k);
            let parent = Rng::new(*seed);
            let mut runs: Vec<(Vec<Vec<f32>>, EfState)> = Vec::new();
            for pool in [Pool::serial(), Pool::new(2), Pool::new(5)] {
                let mut st = EfState::new(n, msgs[0].len());
                let mut all = Vec::new();
                for _ in 0..3 {
                    let mut rngs = parent.split(n);
                    let (out, _) =
                        compress_batch_ef(&comp, &mut st, &msgs, &mut rngs, &pool);
                    all.extend(out);
                }
                runs.push((all, st));
            }
            // the per-device step path, serial
            let mut st = EfState::new(n, msgs[0].len());
            let mut all = Vec::new();
            for _ in 0..3 {
                let mut rngs = parent.split(n);
                for i in 0..n {
                    all.push(st.step(i, msgs[i], &comp, &mut rngs[i]).vec);
                }
            }
            runs.push((all, st));
            for (out, st) in &runs[1..] {
                ensure(*out == runs[0].0, || "messages differ across pool widths".into())?;
                ensure(*st == runs[0].1, || "residuals differ across pool widths".into())?;
            }
            Ok(())
        },
    );
}

/// Top-K reconstruction error is never worse than rand-K in L2 (it is the
/// L2-optimal K-sparse approximation before scaling).
#[test]
fn prop_topk_beats_randk_in_l2() {
    forall(
        40,
        0xB5,
        |rng: &mut Rng| {
            let q = gen::usize_in(rng, 8, 64);
            let k = gen::usize_in(rng, 1, q / 2);
            let g = gen::vec_f32(rng, q, 5.0);
            let seed = rng.next_u64();
            (g, k, seed)
        },
        |(g, k, seed)| {
            let mut rng = Rng::new(*seed);
            let t = TopK::new(*k).compress(g, &mut rng);
            let r = RandK::new(*k).compress(g, &mut rng);
            let et = lad::util::math::dist_sq(&t.vec, g);
            let er = lad::util::math::dist_sq(&r.vec, g);
            ensure(et <= er + 1e-6, || format!("top-k {et} > rand-k {er}"))
        },
    );
}
