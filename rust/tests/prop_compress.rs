//! Property tests on the compression operators (Definition 2).

use lad::compress::{measure_bias_delta, Compressor, Identity, Qsgd, RandK, TopK};
use lad::proptest_lite::{ensure, forall, gen};
use lad::util::rng::Rng;

/// Unbiasedness (eq. 9) for the unbiased operators, across shapes/scales.
#[test]
fn prop_unbiased_operators_are_unbiased() {
    forall(
        12,
        0xB1,
        |rng: &mut Rng| {
            let q = gen::usize_in(rng, 4, 64);
            let k = gen::usize_in(rng, 1, q);
            let scale = 10f32.powi(gen::usize_in(rng, 0, 3) as i32 - 1);
            let g = gen::vec_f32(rng, q, scale);
            let seed = rng.next_u64();
            (g, k, seed)
        },
        |(g, k, seed)| {
            let mut rng = Rng::new(*seed);
            let ops: Vec<Box<dyn Compressor>> =
                vec![Box::new(Identity), Box::new(RandK::new(*k)), Box::new(Qsgd::new(8))];
            for op in ops {
                let (bias, _) = measure_bias_delta(op.as_ref(), g, 8_000, &mut rng);
                ensure(bias < 0.05, || format!("{}: bias {bias}", op.name()))?;
            }
            Ok(())
        },
    );
}

/// δ bound (eq. 10): measured relative error ≤ theoretical δ (+ slack).
#[test]
fn prop_delta_bound_holds() {
    forall(
        12,
        0xB2,
        |rng: &mut Rng| {
            let q = gen::usize_in(rng, 8, 64);
            let k = gen::usize_in(rng, 1, q);
            let g = gen::vec_f32(rng, q, 3.0);
            let seed = rng.next_u64();
            (g, k, seed)
        },
        |(g, k, seed)| {
            let mut rng = Rng::new(*seed);
            let q = g.len();
            for op in [RandK::new(*k)] {
                let bound = op.delta(q).unwrap();
                let (_, d) = measure_bias_delta(&op, g, 8_000, &mut rng);
                ensure(d <= bound * 1.25 + 0.05, || {
                    format!("{}: δ̂ {d} > bound {bound}", op.name())
                })?;
            }
            let qs = Qsgd::new(4);
            let bound = qs.delta(q).unwrap();
            let (_, d) = measure_bias_delta(&qs, g, 8_000, &mut rng);
            ensure(d <= bound * 1.25 + 0.05, || format!("qsgd: δ̂ {d} > bound {bound}"))
        },
    );
}

/// Wire size is monotone in K and never exceeds dense f32.
#[test]
fn prop_bits_monotone_and_bounded() {
    forall(
        40,
        0xB3,
        |rng: &mut Rng| {
            let q = gen::usize_in(rng, 8, 128);
            let g = gen::vec_f32(rng, q, 1.0);
            let seed = rng.next_u64();
            (g, seed)
        },
        |(g, seed)| {
            let mut rng = Rng::new(*seed);
            let q = g.len();
            let mut prev = 0usize;
            for k in [1usize, q / 4 + 1, q / 2 + 1] {
                let c = RandK::new(k.min(q)).compress(g, &mut rng);
                ensure(c.bits >= prev, || format!("bits not monotone at k={k}"))?;
                prev = c.bits;
            }
            let dense = Identity.compress(g, &mut rng);
            let sparse = RandK::new((q / 4).max(1)).compress(g, &mut rng);
            ensure(sparse.bits < dense.bits, || {
                format!("rand-k ({}) not cheaper than dense ({})", sparse.bits, dense.bits)
            })
        },
    );
}

/// Support size: rand-K and top-K keep exactly K nonzeros (for generic g).
#[test]
fn prop_sparsifiers_support_size() {
    forall(
        40,
        0xB4,
        |rng: &mut Rng| {
            let q = gen::usize_in(rng, 4, 64);
            let k = gen::usize_in(rng, 1, q);
            // strictly nonzero entries so support is exactly K
            let g: Vec<f32> = (0..q)
                .map(|_| (rng.f32() + 0.1) * if rng.bernoulli(0.5) { 1.0 } else { -1.0 })
                .collect();
            let seed = rng.next_u64();
            (g, k, seed)
        },
        |(g, k, seed)| {
            let mut rng = Rng::new(*seed);
            for op in [&RandK::new(*k) as &dyn Compressor, &TopK::new(*k)] {
                let c = op.compress(g, &mut rng);
                let nnz = c.vec.iter().filter(|&&x| x != 0.0).count();
                ensure(nnz == *k, || format!("{}: nnz {nnz} != k {k}", op.name()))?;
            }
            Ok(())
        },
    );
}

/// Top-K reconstruction error is never worse than rand-K in L2 (it is the
/// L2-optimal K-sparse approximation before scaling).
#[test]
fn prop_topk_beats_randk_in_l2() {
    forall(
        40,
        0xB5,
        |rng: &mut Rng| {
            let q = gen::usize_in(rng, 8, 64);
            let k = gen::usize_in(rng, 1, q / 2);
            let g = gen::vec_f32(rng, q, 5.0);
            let seed = rng.next_u64();
            (g, k, seed)
        },
        |(g, k, seed)| {
            let mut rng = Rng::new(*seed);
            let t = TopK::new(*k).compress(g, &mut rng);
            let r = RandK::new(*k).compress(g, &mut rng);
            let et = lad::util::math::dist_sq(&t.vec, g);
            let er = lad::util::math::dist_sq(&r.vec, g);
            ensure(et <= er + 1e-6, || format!("top-k {et} > rand-k {er}"))
        },
    );
}
