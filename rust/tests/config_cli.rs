//! Config-file and CLI plumbing integration tests.

use lad::cli::Args;
use lad::config::{AggregatorKind, CompressionKind, TrainConfig};

#[test]
fn config_file_round_trip() {
    let dir = std::env::temp_dir().join("lad_cfg_it");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("train.toml");
    std::fs::write(
        &path,
        r#"
        # Fig-6 style run
        seed = 99
        [train]
        devices = 100
        honest = 70
        d = 3
        dim = 100
        iters = 600
        lr = 3e-7
        sigma_h = 0.3
        aggregator = "cwtm"
        nnm = true
        trim_frac = 0.1
        compression = "rand-k"
        q_hat = 30
        attack = "sign-flip"
        oracle = "native"
        log_every = 50
        "#,
    )
    .unwrap();
    let cfg = TrainConfig::from_file(&path).unwrap();
    assert_eq!(cfg.seed, 99);
    assert_eq!(cfg.n_honest, 70);
    assert_eq!(cfg.compression, CompressionKind::RandK { k: 30 });
    assert!(cfg.nnm);
    assert_eq!(cfg.aggregator, AggregatorKind::Cwtm);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn invalid_configs_rejected_with_context() {
    for (body, needle) in [
        ("devices = 10\nhonest = 4", "H > N/2"),
        ("d = 200", "d"),
        ("lr = -1.0", "lr"),
        ("aggregator = \"bogus\"", "aggregator"),
        ("attack = \"nope\"", "attack"),
        ("whatever = 1", "unknown"),
    ] {
        let err = TrainConfig::from_toml_str(body).unwrap_err();
        let msg = format!("{err:#}").to_lowercase();
        assert!(msg.contains(&needle.to_lowercase()), "{body}: {msg}");
    }
}

#[test]
fn cli_overrides_win_over_defaults() {
    let args = Args::parse(
        ["train", "--devices", "40", "--honest", "30", "--d", "7", "--nnm"]
            .iter()
            .map(|s| s.to_string()),
    )
    .unwrap();
    assert_eq!(args.command.as_deref(), Some("train"));
    assert_eq!(args.get_usize("devices", 100).unwrap(), 40);
    assert_eq!(args.get_usize("honest", 80).unwrap(), 30);
    assert!(args.has_flag("nnm"));
}

#[test]
fn lad_binary_help_and_theory_run() {
    // spawn the actual binary (cheapest end-to-end CLI check)
    let bin = env!("CARGO_BIN_EXE_lad");
    let out = std::process::Command::new(bin).arg("help").output().unwrap();
    assert!(out.status.success());
    assert!(String::from_utf8_lossy(&out.stdout).contains("SUBCOMMANDS"));

    let out = std::process::Command::new(bin)
        .args(["theory", "--n", "100", "--honest", "65", "--d", "5"])
        .output()
        .unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("d crossover"), "{text}");

    // unknown flag is a hard error
    let out = std::process::Command::new(bin)
        .args(["theory", "--bogus", "1"])
        .output()
        .unwrap();
    assert!(!out.status.success());
}

#[test]
fn lad_binary_fig2_fig3_write_csv() {
    let bin = env!("CARGO_BIN_EXE_lad");
    let dir = std::env::temp_dir().join("lad_fig_it");
    std::fs::create_dir_all(&dir).unwrap();
    for fig in ["fig2", "fig3"] {
        let out = std::process::Command::new(bin)
            .args([fig, "--out", dir.to_str().unwrap()])
            .output()
            .unwrap();
        assert!(out.status.success(), "{fig}: {}", String::from_utf8_lossy(&out.stderr));
    }
    assert!(dir.join("fig2_error_vs_delta.csv").exists());
    assert!(dir.join("fig3_error_vs_d.csv").exists());
    std::fs::remove_dir_all(&dir).ok();
}
