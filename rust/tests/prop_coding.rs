//! Property tests on the gradient-coding layer: Lemma 1, encoder
//! unbiasedness and the cyclic matrix's optimality.

use lad::coding::task_matrix::{lemma1_infimum, TaskMatrix};
use lad::coding::{encode_coded, Assignment};
use lad::proptest_lite::{ensure, forall, gen};
use lad::util::math::Mat;
use lad::util::rng::Rng;

/// The closed-form Lemma-1 objective equals the paper's infimum exactly for
/// the cyclic matrix, for every (N, H, d).
#[test]
fn prop_cyclic_attains_infimum() {
    forall(
        200,
        0xC1,
        |rng: &mut Rng| {
            let n = gen::usize_in(rng, 3, 60);
            let h = gen::usize_in(rng, n / 2 + 1, n);
            let d = gen::usize_in(rng, 1, n);
            (n, h, d)
        },
        |&(n, h, d)| {
            let s = TaskMatrix::cyclic(n, d);
            let cf = s.lemma1_objective(h);
            let inf = lemma1_infimum(n, h, d);
            ensure((cf - inf).abs() < 1e-10 * inf.max(1.0), || {
                format!("N={n},H={h},d={d}: closed form {cf} vs infimum {inf}")
            })
        },
    );
}

/// Random d-regular matrices never beat the cyclic matrix (Lemma 1).
#[test]
fn prop_cyclic_is_optimal_among_random() {
    forall(
        60,
        0xC2,
        |rng: &mut Rng| {
            let n = gen::usize_in(rng, 4, 24);
            let h = gen::usize_in(rng, n / 2 + 1, n - 1);
            let d = gen::usize_in(rng, 1, n - 1);
            let rand = TaskMatrix::random(n, d, rng);
            (n, h, d, rand)
        },
        |(n, h, d, rand)| {
            let cyc = TaskMatrix::cyclic(*n, *d).lemma1_objective(*h);
            let r = rand.lemma1_objective(*h);
            ensure(cyc <= r + 1e-10, || {
                format!("N={n},H={h},d={d}: cyclic {cyc} > random {r}")
            })
        },
    );
}

/// Every subset is covered exactly d times under any assignment.
#[test]
fn prop_cyclic_coverage_balanced() {
    forall(
        60,
        0xC3,
        |rng: &mut Rng| {
            let n = gen::usize_in(rng, 3, 40);
            let d = gen::usize_in(rng, 1, n);
            let assign = Assignment::draw(n, rng);
            (n, d, assign)
        },
        |(n, d, assign)| {
            let s = TaskMatrix::cyclic(*n, *d);
            let mut count = vec![0usize; *n];
            for i in 0..*n {
                for sub in assign.subsets_for(s.row(assign.tasks[i])) {
                    count[sub] += 1;
                }
            }
            ensure(count.iter().all(|&c| c == *d), || format!("coverage {count:?}"))
        },
    );
}

/// Encoder linearity: encoding a scaled gradient matrix scales the code.
#[test]
fn prop_encoder_linearity() {
    forall(
        40,
        0xC4,
        |rng: &mut Rng| {
            let n = gen::usize_in(rng, 3, 12);
            let q = gen::usize_in(rng, 1, 8);
            let d = gen::usize_in(rng, 1, n);
            let rows: Vec<Vec<f32>> = (0..n).map(|_| gen::vec_f32(rng, q, 5.0)).collect();
            let alpha = rng.f32() * 4.0 - 2.0;
            let assign = Assignment::draw(n, rng);
            (rows, d, alpha, assign)
        },
        |(rows, d, alpha, assign)| {
            let n = rows.len();
            let g = Mat::from_rows(rows);
            let scaled_rows: Vec<Vec<f32>> =
                rows.iter().map(|r| r.iter().map(|x| alpha * x).collect()).collect();
            let g2 = Mat::from_rows(&scaled_rows);
            let s = TaskMatrix::cyclic(n, *d);
            for i in 0..n {
                let a = encode_coded(&g, s.row(assign.tasks[i]), assign);
                let b = encode_coded(&g2, s.row(assign.tasks[i]), assign);
                for j in 0..a.len() {
                    let want = alpha * a[j];
                    ensure((b[j] - want).abs() <= 1e-3 * want.abs().max(1.0), || {
                        format!("linearity: {} vs {}", b[j], want)
                    })?;
                }
            }
            Ok(())
        },
    );
}

/// Monte-Carlo Lemma 1: the empirical objective matches the closed form for
/// arbitrary d-regular matrices (validates eq. (38)–(41) end to end).
#[test]
fn prop_lemma1_monte_carlo_matches() {
    forall(
        8,
        0xC5,
        |rng: &mut Rng| {
            let n = gen::usize_in(rng, 6, 16);
            let h = gen::usize_in(rng, n / 2 + 1, n - 1);
            let d = gen::usize_in(rng, 1, n - 1);
            let m = TaskMatrix::random(n, d, rng);
            let seed = rng.next_u64();
            (h, m, seed)
        },
        |(h, m, seed)| {
            let mut rng = Rng::new(*seed);
            let mc = m.lemma1_monte_carlo(*h, 30_000, &mut rng);
            let cf = m.lemma1_objective(*h);
            ensure((mc - cf).abs() < 0.2 * cf.max(1e-4), || {
                format!("mc {mc} vs cf {cf}")
            })
        },
    );
}
