//! Integration tests for the observability layer: the live status
//! endpoint over real loopback sockets, journal content (with correct
//! attribution) through the churn drill, trainer span plumbing, and the
//! metrics / Chrome-trace exporters. The *parity* guarantees (recorder
//! on/off bit-identity across the config lattice) live in
//! `fuzz_determinism.rs`; this file pins the affirmative side — that
//! the telemetry actually says the right things.

use std::io::Read as _;
use std::sync::Arc;
use std::time::Duration;

use lad::aggregation::{Aggregator as _, Cwtm};
use lad::attack::SignFlip;
use lad::config::{CompressionKind, TrainConfig};
use lad::data::linreg::LinRegDataset;
use lad::net::{LeaderOpts, MISS_RETIRE_STREAK};
use lad::obs::{replay, Event, JsonlRecorder, Metrics, NullRecorder, Obs, RunTimeline, StatusState};
use lad::server::cluster::{
    run_cluster_churn, run_cluster_kill_resume, run_cluster_with, ChurnPlan, ClusterOpts,
};
use lad::server::Trainer;
use lad::util::json::{self, Json};
use lad::util::parallel::Pool;
use lad::util::rng::Rng;

/// Read one status snapshot: connect, read to EOF, parse.
fn poll_status_tcp(addr: &str) -> Json {
    let hostport = addr.strip_prefix("tcp://").expect("tcp status addr");
    let mut conn = std::net::TcpStream::connect(hostport).expect("connecting to status");
    let mut body = String::new();
    conn.read_to_string(&mut body).expect("reading snapshot");
    json::parse(&body).expect("snapshot parses as JSON")
}

#[test]
fn status_endpoint_serves_fresh_snapshots_over_tcp() {
    let (obs, server) = Obs::builder()
        .status_addr("tcp://127.0.0.1:0")
        .build()
        .expect("building status obs");
    let server = server.expect("status server spawned");
    let st = obs.status().expect("status state attached").clone();
    st.begin_run("drill", 40, 3);
    st.set_iter(7);
    st.set_phase("gather");
    st.device_miss(1, 2);
    obs.add("wire_up_bytes", 123);

    let snap = poll_status_tcp(server.addr());
    assert_eq!(snap.get("label").and_then(Json::as_str), Some("drill"));
    assert_eq!(snap.get("iter").and_then(Json::as_f64), Some(7.0));
    assert_eq!(snap.get("phase").and_then(Json::as_str), Some("gather"));
    let roster = snap.get("roster").and_then(Json::as_arr).expect("roster");
    assert_eq!(roster.len(), 3);
    assert_eq!(roster[1].get("miss_streak").and_then(Json::as_f64), Some(2.0));
    assert_eq!(
        snap.get("metrics")
            .and_then(|m| m.get("counters"))
            .and_then(|c| c.get("wire_up_bytes"))
            .and_then(Json::as_f64),
        Some(123.0)
    );

    // one snapshot per connection: a second poll sees newer state
    st.set_iter(9);
    st.device_retired(2);
    let snap2 = poll_status_tcp(server.addr());
    assert_eq!(snap2.get("iter").and_then(Json::as_f64), Some(9.0));
    let roster2 = snap2.get("roster").and_then(Json::as_arr).expect("roster");
    assert_eq!(roster2[2].get("dead"), Some(&Json::Bool(true)));
    server.stop();
}

#[cfg(unix)]
#[test]
fn status_endpoint_serves_snapshots_over_uds() {
    let path = std::env::temp_dir().join(format!("lad_obs_status_{}.sock", std::process::id()));
    let (obs, server) = Obs::builder()
        .status_addr(format!("uds:{}", path.display()))
        .build()
        .expect("building uds status obs");
    let server = server.expect("status server spawned");
    let st = obs.status().expect("status state attached").clone();
    st.begin_run("uds-drill", 10, 2);
    st.set_phase("broadcast");

    let sock = server.addr().strip_prefix("uds:").expect("uds status addr").to_string();
    let mut conn = std::os::unix::net::UnixStream::connect(&sock).expect("connecting to uds");
    let mut body = String::new();
    conn.read_to_string(&mut body).expect("reading snapshot");
    let snap = json::parse(&body).expect("snapshot parses as JSON");
    assert_eq!(snap.get("label").and_then(Json::as_str), Some("uds-drill"));
    assert_eq!(snap.get("phase").and_then(Json::as_str), Some("broadcast"));
    server.stop();
    let _ = std::fs::remove_file(&path);
}

#[test]
fn status_state_is_shareable_without_a_server() {
    // the leader only sees Arc<StatusState>; it must be usable (and
    // snapshot-able) without any acceptor thread behind it
    let st = StatusState::new(Arc::new(Metrics::default()));
    st.begin_run("bare", 5, 1);
    st.device_answered(0);
    assert_eq!(st.snapshot_json().get("label").and_then(Json::as_str), Some("bare"));
}

fn churn_cfg() -> TrainConfig {
    // mirrors the deterministic churn drill in `net_cluster.rs`
    let mut c = TrainConfig::default();
    c.n_devices = 5;
    c.n_honest = 4;
    c.d = 2;
    c.dim = 6;
    c.iters = 16;
    c.lr = 8e-5;
    c.sigma_h = 0.3;
    c.log_every = 4;
    c.compression = CompressionKind::EfQsgd { levels: 16 };
    c
}

fn run_churn(obs: Obs) -> lad::server::TrainTrace {
    let c = churn_cfg();
    let mut rng = Rng::new(1401);
    let ds = LinRegDataset::generate(c.n_devices, c.dim, c.sigma_h, &mut rng);
    let flip = SignFlip { coeff: -2.0 };
    let comp = lad::compress::from_kind(c.compression);
    let pool = Pool::serial();
    let cwtm = Cwtm::new(0.1);
    let opts = ClusterOpts {
        leader: LeaderOpts {
            gather_deadline: Some(Duration::from_millis(200)),
            obs,
            ..Default::default()
        },
        ..Default::default()
    };
    let plan = ChurnPlan { victim: 1, depart_iter: 4, rejoin_iter: 7 };
    let mut x0 = vec![0.0f32; c.dim];
    run_cluster_churn(
        &c,
        &ds,
        &cwtm,
        &flip,
        comp.as_ref(),
        &mut x0,
        "churn",
        &mut Rng::new(1402),
        &pool,
        &opts,
        plan,
    )
    .expect("churn drill failed")
}

#[test]
fn churn_drill_journals_retirement_and_rejoin_with_attribution() {
    let journal =
        std::env::temp_dir().join(format!("lad_obs_churn_{}.jsonl", std::process::id()));
    let obs = Obs::recording(Box::new(JsonlRecorder::create(&journal).expect("journal")));
    let tr = run_churn(obs.clone());
    obs.finish().expect("flush");
    let body = std::fs::read_to_string(&journal).expect("journal readable");
    let _ = std::fs::remove_file(&journal);
    // the journal is shard-appended; reconstruct emission order by seq
    let mut tagged: Vec<(u64, Event)> = body
        .lines()
        .filter_map(|l| json::parse(l).ok())
        .filter_map(|j| {
            let seq = j.get("seq").and_then(Json::as_f64)? as u64;
            Some((seq, Event::from_json(&j)?))
        })
        .collect();
    tagged.sort_by_key(|(seq, _)| *seq);
    let events: Vec<Event> = tagged.into_iter().map(|(_, e)| e).collect();

    // the trace's breakdown counters agree with the drill shape…
    assert_eq!(tr.deadline_misses, MISS_RETIRE_STREAK as u64, "one miss per deadline");
    assert_eq!(tr.retirements, 1, "exactly the victim retires");
    assert_eq!(tr.rejoins, 1, "exactly the replacement rejoins");
    assert_eq!(tr.anomalies, MISS_RETIRE_STREAK, "anomalies unchanged by obs");

    // …and the journal attributes every step to the victim's slot
    let misses: Vec<u64> = events
        .iter()
        .filter_map(|e| match e {
            Event::DeadlineMiss { device: 1, streak, .. } => Some(*streak),
            _ => None,
        })
        .collect();
    assert_eq!(misses, vec![1, 2, 3], "miss streak for the victim: {body}");
    assert!(
        events.iter().any(|e| matches!(e, Event::DeviceRetired { device: 1, reason, .. }
            if reason.contains("consecutive deadline misses"))),
        "no structured retirement for the victim: {body}"
    );
    assert!(
        events
            .iter()
            .any(|e| matches!(e, Event::DeviceRejoined { device: 1, epoch: 1, .. })),
        "no rejoin with a bumped epoch for the victim: {body}"
    );
    // nobody else was touched
    assert!(
        !events.iter().any(|e| matches!(e,
            Event::DeviceRetired { device, .. } | Event::DeviceRejoined { device, .. }
                if *device != 1)),
        "retirement/rejoin attributed to a non-victim device: {body}"
    );
}

/// The golden-journal fixture: the churn drill's journal, replayed
/// through [`RunTimeline`], must reconstruct exactly the membership
/// history the [`ChurnPlan`] scripted — the read side of the
/// observability layer agreeing with the write side end-to-end.
#[test]
fn journal_replay_reconstructs_the_churn_plan() {
    let journal =
        std::env::temp_dir().join(format!("lad_obs_replay_{}.jsonl", std::process::id()));
    let obs = Obs::recording(Box::new(JsonlRecorder::create(&journal).expect("journal")));
    run_churn(obs.clone());
    obs.finish().expect("flush");
    let tl = RunTimeline::from_journal(&journal).expect("replay");
    let _ = std::fs::remove_file(&journal);

    // the plan: victim 1 departs at iter 4, retires after
    // MISS_RETIRE_STREAK misses, replacement activates at iter ≥ 7
    let victim = &tl.devices[1];
    let streaks: Vec<u64> = victim.misses.iter().map(|&(_, s)| s).collect();
    assert_eq!(streaks, vec![1, 2, 3], "victim's miss streak: {tl:?}");
    assert!(
        victim.misses.iter().all(|&(iter, _)| iter >= 4),
        "no miss before the scripted departure: {tl:?}"
    );
    assert_eq!(victim.retires.len(), 1, "exactly one retirement: {tl:?}");
    assert!(
        victim.retires[0].1.contains("consecutive deadline misses"),
        "retirement reason survives replay: {tl:?}"
    );
    assert_eq!(victim.rejoins.len(), 1, "exactly one rejoin: {tl:?}");
    let (rejoin_iter, rejoin_epoch) = victim.rejoins[0];
    assert!(rejoin_iter >= 7, "activation respects the plan's not-before gate: {tl:?}");
    assert_eq!(rejoin_epoch, 1, "rejoin bumps the slot epoch: {tl:?}");
    for (i, d) in tl.devices.iter().enumerate() {
        if i != 1 {
            assert!(
                d.retires.is_empty() && d.rejoins.is_empty() && d.misses.is_empty(),
                "churn leaked onto device {i}: {tl:?}"
            );
        }
    }
    // the rendered timeline (the CI artifact) narrates the same facts
    let text = tl.render();
    assert!(text.contains("device 1:"), "{text}");
    assert!(text.contains("deadline miss (streak 3)"), "{text}");
    assert!(text.contains("retired:"), "{text}");
    assert!(text.contains("rejoined (epoch 1)"), "{text}");
}

/// Two same-seed churn drills journal structurally identical histories:
/// `replay::diff` sees zero divergences even though wall-clock envelope
/// fields differ between the runs.
#[test]
fn diff_of_two_same_seed_runs_is_empty() {
    let run_to_journal = |tag: &str| {
        let journal = std::env::temp_dir()
            .join(format!("lad_obs_selfdiff_{tag}_{}.jsonl", std::process::id()));
        let obs =
            Obs::recording(Box::new(JsonlRecorder::create(&journal).expect("journal")));
        run_churn(obs.clone());
        obs.finish().expect("flush");
        let tl = RunTimeline::from_journal(&journal).expect("replay");
        let _ = std::fs::remove_file(&journal);
        tl
    };
    let a = run_to_journal("a");
    let b = run_to_journal("b");
    assert_eq!(a.events, b.events, "same-seed runs journal the same event count");
    let divs = replay::diff(&a, &b);
    assert!(divs.is_empty(), "same-seed runs must not diverge: {divs:?}");
}

/// A kill/resume run diffed against an uninterrupted same-seed run
/// diverges *only* in the checkpoint and failover categories — the
/// membership history is untouched by the warm restart.
#[test]
fn kill_resume_diverges_from_uninterrupted_only_in_checkpoint_and_failover() {
    use lad::compress::Identity;

    let mut cfg = TrainConfig::default();
    cfg.n_devices = 5;
    cfg.n_honest = 4;
    cfg.d = 2;
    cfg.dim = 6;
    cfg.iters = 12;
    cfg.lr = 8e-5;
    cfg.sigma_h = 0.3;
    cfg.log_every = 4;
    let mut rng = Rng::new(1501);
    let ds = LinRegDataset::generate(cfg.n_devices, cfg.dim, cfg.sigma_h, &mut rng);
    let cwtm = Cwtm::new(0.1);
    let flip = SignFlip { coeff: -2.0 };
    let pool = Pool::serial();
    let dir = std::env::temp_dir().join(format!("lad_obs_krdiff_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();

    let journal_kr = dir.join("kill_resume.jsonl");
    let obs = Obs::recording(Box::new(JsonlRecorder::create(&journal_kr).expect("journal")));
    let opts = ClusterOpts {
        leader: LeaderOpts { obs, ..Default::default() },
        ..Default::default()
    };
    let mut x_kr = vec![0.0f32; cfg.dim];
    let tr_kr = run_cluster_kill_resume(
        &cfg,
        &ds,
        &cwtm,
        &flip,
        &Identity,
        &mut x_kr,
        "kr",
        &mut Rng::new(1502),
        &pool,
        &opts,
        5,
        &dir.join("ckpt.bin"),
    )
    .expect("kill/resume drill");
    opts.leader.obs.finish().expect("flush");

    let journal_full = dir.join("uninterrupted.jsonl");
    let obs = Obs::recording(Box::new(JsonlRecorder::create(&journal_full).expect("journal")));
    let opts = ClusterOpts {
        leader: LeaderOpts { obs, ..Default::default() },
        ..Default::default()
    };
    let mut x_full = vec![0.0f32; cfg.dim];
    let tr_full = run_cluster_with(
        &cfg,
        &ds,
        &cwtm,
        &flip,
        &Identity,
        &mut x_full,
        "full",
        &mut Rng::new(1502),
        &pool,
        &opts,
    )
    .expect("uninterrupted run");
    opts.leader.obs.finish().expect("flush");

    // sanity: the warm restart itself is trace-identical
    assert_eq!(x_kr, x_full, "resume must reproduce the uninterrupted iterate");
    assert_eq!(tr_kr.loss, tr_full.loss);

    let kr = RunTimeline::from_journal(&journal_kr).expect("replay kill/resume");
    let full = RunTimeline::from_journal(&journal_full).expect("replay uninterrupted");
    let _ = std::fs::remove_dir_all(&dir);

    assert_eq!(kr.checkpoints.len(), 1, "halting leader cut one checkpoint: {kr:?}");
    assert_eq!(kr.failovers.len(), 1, "resume journaled the warm restart: {kr:?}");
    assert!(full.checkpoints.is_empty() && full.failovers.is_empty(), "{full:?}");

    let divs = replay::diff(&kr, &full);
    assert!(!divs.is_empty(), "the checkpoint/failover difference must be visible");
    assert!(
        replay::only_in(&divs, &["checkpoint", "failover"]),
        "membership history diverged beyond checkpoint/failover: {divs:?}"
    );
}

#[test]
fn churn_drill_trace_is_identical_with_the_recorder_off() {
    let off = run_churn(Obs::off());
    let on = run_churn(Obs::recording(Box::new(NullRecorder)));
    assert_eq!(off.loss, on.loss, "loss trace perturbed by the recorder");
    assert_eq!(off.grad_update_norm, on.grad_update_norm);
    assert_eq!(off.bits, on.bits, "bit accounting perturbed by the recorder");
    assert_eq!(off.final_loss, on.final_loss);
    assert_eq!(off.anomalies, on.anomalies);
    assert_eq!(
        (off.deadline_misses, off.retirements, off.rejoins),
        (on.deadline_misses, on.retirements, on.rejoins),
        "elasticity counters perturbed by the recorder"
    );
}

fn trainer_cfg() -> TrainConfig {
    let mut cfg = TrainConfig::default();
    cfg.n_devices = 8;
    cfg.n_honest = 6;
    cfg.d = 2;
    cfg.dim = 8;
    cfg.iters = 20;
    cfg.lr = 1e-4;
    cfg.sigma_h = 0.3;
    cfg.log_every = 5;
    cfg
}

#[test]
fn trainer_spans_feed_histograms_without_perturbing_the_trace() {
    use lad::attack::NoAttack;
    use lad::compress::Identity;
    use lad::grad::NativeLinReg;

    let cfg = trainer_cfg();
    let cwtm = Cwtm::new(0.1);
    let run = |obs: Option<&Obs>| {
        let mut rng = Rng::new(77);
        let ds = LinRegDataset::generate(cfg.n_devices, cfg.dim, cfg.sigma_h, &mut rng);
        let mut oracle = NativeLinReg::new(ds);
        let mut x0 = vec![0.0f32; cfg.dim];
        let mut trainer = Trainer::new(&cfg, &cwtm, &NoAttack, &Identity);
        if let Some(o) = obs {
            trainer = trainer.with_obs(o);
        }
        trainer.run(&mut oracle, &mut x0, "obs-central", &mut rng).expect("run")
    };
    let off = run(None);
    let obs = Obs::recording(Box::new(NullRecorder));
    let on = run(Some(&obs));
    assert_eq!(off.loss, on.loss, "central trace perturbed by obs");
    assert_eq!(off.final_loss, on.final_loss);
    assert_eq!(off.bits, on.bits);

    let m = obs.metrics().expect("metrics attached");
    for phase in ["oracle", "craft", "compress", "aggregate"] {
        assert_eq!(
            m.histogram(phase).count(),
            cfg.iters as u64,
            "one {phase} span per iteration"
        );
    }
    assert_eq!(
        m.histogram(&format!("aggregate_kernel/{}", cwtm.name())).count(),
        cfg.iters as u64,
        "per-rule kernel histogram keyed by aggregator name"
    );
}

#[test]
fn metrics_and_chrome_trace_exports_are_valid_json() {
    use lad::attack::NoAttack;
    use lad::compress::Identity;
    use lad::grad::NativeLinReg;

    let dir = std::env::temp_dir().join(format!("lad_obs_export_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let metrics_path = dir.join("metrics.json");
    let trace_path = dir.join("trace.json");
    let (obs, server) = Obs::builder()
        .metrics_out(&metrics_path)
        .trace_out(&trace_path)
        .build()
        .expect("building export obs");
    assert!(server.is_none(), "no status server without --status-addr");

    let cfg = trainer_cfg();
    let cwtm = Cwtm::new(0.1);
    let mut rng = Rng::new(78);
    let ds = LinRegDataset::generate(cfg.n_devices, cfg.dim, cfg.sigma_h, &mut rng);
    let mut oracle = NativeLinReg::new(ds);
    let mut x0 = vec![0.0f32; cfg.dim];
    Trainer::new(&cfg, &cwtm, &NoAttack, &Identity)
        .with_obs(&obs)
        .run(&mut oracle, &mut x0, "obs-export", &mut rng)
        .expect("run");
    obs.finish().expect("export");

    let metrics = json::parse(&std::fs::read_to_string(&metrics_path).unwrap())
        .expect("metrics.json parses");
    assert_eq!(
        metrics
            .get("histograms")
            .and_then(|h| h.get("oracle"))
            .and_then(|h| h.get("count"))
            .and_then(Json::as_f64),
        Some(cfg.iters as f64),
        "metrics snapshot carries the span histograms"
    );

    let trace = json::parse(&std::fs::read_to_string(&trace_path).unwrap())
        .expect("trace.json parses");
    let evs = trace.get("traceEvents").and_then(Json::as_arr).expect("traceEvents array");
    assert!(
        evs.len() >= 4 * cfg.iters,
        "expected ≥ {} span events, got {}",
        4 * cfg.iters,
        evs.len()
    );
    for ev in evs.iter().take(5) {
        assert_eq!(ev.get("ph").and_then(Json::as_str), Some("X"));
        assert!(ev.get("name").and_then(Json::as_str).is_some());
        assert!(ev.get("ts").and_then(Json::as_f64).is_some());
        assert!(ev.get("dur").and_then(Json::as_f64).is_some());
    }
    let _ = std::fs::remove_dir_all(&dir);
}
