//! Sweep-engine integration tests: the figure-delegation invariant
//! (engine traces bit-identical to direct runs), the interrupted-resume
//! contract (a sweep killed mid-run and resumed emits JSONL bit-identical
//! to an uninterrupted run), and the partial-participation path through
//! the net leader's retirement machinery.

use lad::config::{AggregatorKind, AttackKind, CompressionKind, TrainConfig};
use lad::data::linreg::LinRegDataset;
use lad::experiments::common::{run_variant_in, Variant};
use lad::server::TrainTrace;
use lad::sweep::{self, queue, SweepSpec};
use lad::util::parallel::{Parallelism, Pool};
use lad::util::rng::Rng;
use std::path::PathBuf;

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("lad_sweep_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn assert_traces_identical(a: &TrainTrace, b: &TrainTrace, what: &str) {
    assert_eq!(a.iters, b.iters, "{what}: sampled iterations differ");
    assert_eq!(a.loss, b.loss, "{what}: loss trace differs");
    assert_eq!(a.grad_update_norm, b.grad_update_norm, "{what}: update norms differ");
    assert_eq!(a.bits, b.bits, "{what}: bit accounting differs");
    assert_eq!(a.final_loss, b.final_loss, "{what}: final loss differs");
}

#[test]
fn engine_traces_match_direct_variant_runs() {
    // the delegation invariant behind the fig4/5/6/byz-sweep refactor:
    // wrapping a variant list as sweep jobs and executing through the
    // engine must reproduce run_variant_in bit-for-bit
    let mut base = TrainConfig::default();
    base.n_devices = 12;
    base.n_honest = 9;
    base.d = 3;
    base.dim = 10;
    base.iters = 30;
    base.lr = 5e-5;
    base.sigma_h = 0.3;
    base.log_every = 10;
    let mut variants = Vec::new();
    for (label, agg, comp) in [
        ("cwtm", AggregatorKind::Cwtm, CompressionKind::None),
        ("krum-randk", AggregatorKind::Krum, CompressionKind::RandK { k: 4 }),
        ("median-qsgd", AggregatorKind::Median, CompressionKind::Qsgd { levels: 8 }),
    ] {
        let mut cfg = base.clone();
        cfg.aggregator = agg;
        cfg.compression = comp;
        variants.push(Variant { label: label.into(), cfg, draco_r: None });
    }
    let (data_seed, run_seed) = (401u64, 402u64);
    let jobs = sweep::jobs_from_variants(&variants, data_seed, run_seed);
    let engine = queue::execute(&jobs, Parallelism::new(3)).unwrap();
    let mut rng = Rng::new(data_seed);
    let ds = LinRegDataset::generate(base.n_devices, base.dim, base.sigma_h, &mut rng);
    for (v, tr) in variants.iter().zip(&engine) {
        let direct = run_variant_in(&ds, v, run_seed, &Pool::serial()).unwrap();
        assert_traces_identical(tr, &direct, &v.label);
    }
}

const RESUME_SPEC: &str = r#"
    [sweep]
    name = "resume_grid"
    q_hat = 3

    [fixed]
    devices = 10
    honest = 8
    dim = 8
    d = 2
    iters = 15
    lr = 1e-4
    log_every = 5
    seed = 77

    [grid]
    attack = ["sign-flip", "alie", "zero"]
    rule = ["cwtm", "krum", "median"]
    compressor = ["none", "rand-k"]
"#;

#[test]
fn interrupted_resume_emits_bit_identical_results() {
    let spec = SweepSpec::from_toml_str(RESUME_SPEC).unwrap();
    assert_eq!(spec.expand().unwrap().len(), 18, "3 attacks x 3 rules x 2 compressors");

    // leg 1: "killed" after 5 jobs (the deterministic interruption hook)
    let dir_a = tmp_dir("resume_a");
    let leg1 =
        queue::run_sweep(&spec, &dir_a, false, Some(5), Parallelism::new(2)).unwrap();
    assert_eq!(leg1.ran, 5);
    assert_eq!(leg1.pending, 13);
    assert!(leg1.results_path.is_none(), "incomplete sweeps must not write results");
    assert!(leg1.manifest_path.exists());

    // simulate the kill landing mid-append: a torn, unparseable final
    // line in the journal — resume must compact it away, not glue the
    // next record onto it
    {
        use std::io::Write as _;
        let mut f =
            std::fs::OpenOptions::new().append(true).open(&leg1.manifest_path).unwrap();
        write!(f, "{{\"id\": \"feedface\", \"final_lo").unwrap();
    }

    // leg 2: resume to completion
    let leg2 = queue::run_sweep(&spec, &dir_a, true, None, Parallelism::new(2)).unwrap();
    assert_eq!(leg2.skipped, 5, "journaled jobs are not rerun");
    assert_eq!(leg2.ran, 13);
    assert_eq!(leg2.pending, 0);
    // the compacted journal is fully parseable — the torn tail is gone
    let journal = lad::sweep::sink::read_manifest(&leg2.manifest_path).unwrap();
    assert_eq!(journal.len(), 18);
    let results_a = std::fs::read(leg2.results_path.as_ref().unwrap()).unwrap();
    let csv_a = std::fs::read(leg2.csv_path.as_ref().unwrap()).unwrap();

    // reference: one uninterrupted run in a fresh directory
    let dir_b = tmp_dir("resume_b");
    let full = queue::run_sweep(&spec, &dir_b, false, None, Parallelism::new(4)).unwrap();
    assert_eq!(full.ran, 18);
    let results_b = std::fs::read(full.results_path.as_ref().unwrap()).unwrap();
    let csv_b = std::fs::read(full.csv_path.as_ref().unwrap()).unwrap();

    assert!(
        results_a == results_b,
        "interrupted+resumed results.jsonl differs from the uninterrupted run"
    );
    assert_eq!(csv_a, csv_b, "pivot CSVs diverged");
    assert!(!results_a.is_empty());
    let first = String::from_utf8(results_a.clone()).unwrap();
    let first = first.lines().next().unwrap();
    assert!(first.contains("\"final_loss\"") && first.contains("\"id\""));

    // a third resume call is a no-op that still (re)writes identical output
    let noop = queue::run_sweep(&spec, &dir_a, true, None, Parallelism::new(1)).unwrap();
    assert_eq!(noop.ran, 0);
    assert_eq!(noop.skipped, 18);
    assert_eq!(std::fs::read(noop.results_path.unwrap()).unwrap(), results_b);

    // a fresh partial rerun into a completed directory must clear the old
    // results files — an incomplete sweep leaves no stale output behind
    let partial = queue::run_sweep(&spec, &dir_b, false, Some(2), Parallelism::new(1)).unwrap();
    assert_eq!(partial.ran, 2);
    assert!(partial.results_path.is_none());
    assert!(!dir_b.join("results.jsonl").exists(), "stale results.jsonl survived a fresh rerun");
    assert!(!dir_b.join("results.csv").exists(), "stale results.csv survived a fresh rerun");

    std::fs::remove_dir_all(&dir_a).ok();
    std::fs::remove_dir_all(&dir_b).ok();
}

#[test]
fn stall_jobs_run_the_retirement_path_deterministically() {
    // the ROADMAP partial-participation workload: stalling workers under
    // a gather deadline, driven through the net leader (miss accounting +
    // chronic-straggler retirement). With a generous deadline the miss
    // set is exactly the seeded stall set, so two runs are bit-identical.
    let spec = SweepSpec::from_toml_str(
        r#"
        [sweep]
        name = "stall_unit"

        [fixed]
        devices = 12
        honest = 9
        dim = 8
        d = 2
        iters = 6
        lr = 1e-4
        log_every = 3
        seed = 55

        # generous vs the in-process microsecond uploads, so an honest
        # worker descheduled on a loaded CI runner still makes the
        # deadline — the miss set must be exactly the seeded stall set
        [net]
        gather_deadline_ms = 700

        [grid]
        stall_prob = [0.0, 0.45]
        "#,
    )
    .unwrap();
    let jobs = spec.expand().unwrap();
    assert_eq!(jobs.len(), 2);

    // the stall-free job through the deadline path matches the central
    // fast path exactly (all devices live)
    let live = queue::run_job(&jobs[0], &Pool::serial()).unwrap();
    let mut rng = Rng::new(jobs[0].data_seed);
    let ds = LinRegDataset::generate(12, 8, jobs[0].cfg.sigma_h, &mut rng);
    let central = run_variant_in(
        &ds,
        &Variant { label: "central".into(), cfg: jobs[0].cfg.clone(), draco_r: None },
        jobs[0].run_seed,
        &Pool::serial(),
    )
    .unwrap();
    assert_traces_identical(&live, &central, "deadline path, all live");
    assert_eq!(live.anomalies, 0);

    // the stalling job: misses recorded, run completes, and reruns are
    // bit-identical (stall decisions come from seeded private streams)
    let a = queue::run_job(&jobs[1], &Pool::serial()).unwrap();
    assert!(a.anomalies > 0, "stall_prob=0.45 over 6 iterations must miss at least once");
    assert!(a.final_loss.is_finite());
    let b = queue::run_job(&jobs[1], &Pool::serial()).unwrap();
    assert_traces_identical(&a, &b, "stall job rerun");
    assert_eq!(a.anomalies, b.anomalies, "anomaly accounting must be deterministic");
}

#[test]
fn ef_and_momentum_axes_expand_with_a_pinned_job_id() {
    // the new first-class arms: `ef-*` compressor values and the
    // `momentum-filter` rule expand like any other axis value, with
    // stable, distinct, content-addressed ids
    let src = r#"
        [grid]
        rule = ["cwtm", "momentum-filter"]
        compressor = ["none", "qsgd", "ef-qsgd", "ef-rand-k"]
    "#;
    let jobs = SweepSpec::from_toml_str(src).unwrap().expand().unwrap();
    assert_eq!(jobs.len(), 2 * 4);
    let ids: std::collections::BTreeSet<_> = jobs.iter().map(|j| j.id.clone()).collect();
    assert_eq!(ids.len(), jobs.len(), "new arms must content-address distinctly");
    let again = SweepSpec::from_toml_str(src).unwrap().expand().unwrap();
    for (a, b) in jobs.iter().zip(&again) {
        assert_eq!(a.id, b.id, "re-expansion must reproduce every id");
    }
    // EF arms inherit the base-operator parameters (spec q_hat / levels)
    assert!(jobs
        .iter()
        .any(|j| j.cfg.compression == CompressionKind::EfQsgd { levels: 16 }));
    assert!(jobs.iter().any(|j| j.cfg.compression == CompressionKind::EfRandK { k: 30 }));
    assert!(jobs.iter().any(|j| j.cfg.aggregator.name() == "momentum-filter"));
    // one literal pin, FNV-1a 64 computed independently of job_id: an
    // accidental change to the canonical encoding of the new arms (or a
    // new unconditional canonical field) fails loudly here
    let mut cfg = TrainConfig::default();
    cfg.aggregator = AggregatorKind::MomentumFilter;
    cfg.compression = CompressionKind::EfQsgd { levels: 16 };
    let job = sweep::Job::from_variant(
        &Variant { label: "pin".into(), cfg, draco_r: None },
        7,
        11,
    );
    let canon = job.canonical();
    assert!(
        canon.contains("agg=momentum-filter") && canon.contains("comp=ef-qsgd:16"),
        "canonical lost the new arms: {canon}"
    );
    assert_eq!(job.id, "d60381fe3154a832");
}

#[test]
fn ef_vs_coding_preset_resume_is_bit_identical() {
    // the new preset (LAD / Com-LAD / EF-compression / momentum-filter
    // from one rule x compressor grid) through the interrupt + --resume
    // contract; the two legs and the reference run use different thread
    // counts, so this also pins thread-count invariance for the new arms
    let spec = lad::sweep::scenarios::preset("ef-vs-coding").unwrap();
    assert_eq!(spec.expand().unwrap().len(), 6, "2 rules x 3 compressors");

    let dir_a = tmp_dir("efvc_a");
    let leg1 = queue::run_sweep(&spec, &dir_a, false, Some(2), Parallelism::new(2)).unwrap();
    assert_eq!(leg1.ran, 2);
    assert!(leg1.results_path.is_none(), "incomplete sweeps must not write results");
    let leg2 = queue::run_sweep(&spec, &dir_a, true, None, Parallelism::new(2)).unwrap();
    assert_eq!(leg2.skipped, 2, "journaled jobs are not rerun");
    assert_eq!(leg2.ran, 4);
    let results_a = std::fs::read(leg2.results_path.as_ref().unwrap()).unwrap();
    let csv_a = std::fs::read(leg2.csv_path.as_ref().unwrap()).unwrap();

    let dir_b = tmp_dir("efvc_b");
    let full = queue::run_sweep(&spec, &dir_b, false, None, Parallelism::new(4)).unwrap();
    assert_eq!(full.ran, 6);
    let results_b = std::fs::read(full.results_path.as_ref().unwrap()).unwrap();
    let csv_b = std::fs::read(full.csv_path.as_ref().unwrap()).unwrap();

    assert!(
        results_a == results_b,
        "interrupted+resumed ef-vs-coding results.jsonl differs from the uninterrupted run"
    );
    assert_eq!(csv_a, csv_b, "ef-vs-coding pivot CSVs diverged");
    let body = String::from_utf8(results_a).unwrap();
    assert!(
        body.contains("\"momentum-filter\"") && body.contains("\"ef-qsgd\""),
        "the new arms are missing from the journaled records"
    );
    std::fs::remove_dir_all(&dir_a).ok();
    std::fs::remove_dir_all(&dir_b).ok();
}

#[test]
fn quickstart_example_spec_parses_and_expands() {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../examples/sweep_quickstart.toml");
    let spec = SweepSpec::from_file(path).unwrap();
    let jobs = spec.expand().unwrap();
    // the documented acceptance shape: >=3 attacks x >=3 rules x 2 compressors
    assert!(jobs.len() >= 18, "quickstart grid shrank to {} jobs", jobs.len());
    let attacks: std::collections::BTreeSet<_> =
        jobs.iter().map(|j| j.cfg.attack.name()).collect();
    let rules: std::collections::BTreeSet<_> =
        jobs.iter().map(|j| j.cfg.aggregator.name()).collect();
    let comps: std::collections::BTreeSet<_> =
        jobs.iter().map(|j| j.cfg.compression.name()).collect();
    assert!(attacks.len() >= 3, "attacks: {attacks:?}");
    assert!(rules.len() >= 3, "rules: {rules:?}");
    assert!(comps.len() >= 2, "compressors: {comps:?}");
}

#[test]
fn smoke_example_spec_is_ci_sized() {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../examples/sweep_smoke.toml");
    let spec = SweepSpec::from_file(path).unwrap();
    let jobs = spec.expand().unwrap();
    assert!(
        (2..=8).contains(&jobs.len()),
        "CI smoke spec must stay tiny, got {} jobs",
        jobs.len()
    );
    assert!(jobs.iter().all(|j| j.cfg.iters <= 30), "smoke jobs must be short");
}

#[test]
fn ef_vs_coding_example_spec_is_ci_sized() {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../examples/ef_vs_coding.toml");
    let spec = SweepSpec::from_file(path).unwrap();
    let jobs = spec.expand().unwrap();
    assert_eq!(jobs.len(), 6, "2 rules x 3 compressors");
    assert!(jobs.iter().all(|j| j.cfg.iters <= 30), "smoke jobs must be short");
    // all four algorithm arms are present in the grid
    let arms: std::collections::BTreeSet<_> = jobs
        .iter()
        .map(|j| (j.cfg.aggregator.name().to_string(), j.cfg.compression.name().to_string()))
        .collect();
    assert!(arms.contains(&("cwtm".to_string(), "none".to_string())));
    assert!(arms.contains(&("cwtm".to_string(), "qsgd".to_string())));
    assert!(arms.contains(&("cwtm".to_string(), "ef-qsgd".to_string())));
    assert!(arms.iter().any(|(r, _)| r == "momentum-filter"));
    // the [sweep] levels key flowed into both qsgd-family compressor arms
    assert!(jobs
        .iter()
        .any(|j| j.cfg.compression == lad::config::CompressionKind::EfQsgd { levels: 8 }));
}

#[test]
fn attack_kind_detail_reaches_the_job_config() {
    // AttackKind axis values carry their canonical parameters; the stall
    // probability of one job never leaks into its siblings
    let spec = SweepSpec::from_toml_str(
        r#"
        [net]
        gather_deadline_ms = 100
        [grid]
        attack = ["ipm", "gaussian"]
        stall_prob = [0.0, 0.2]
        "#,
    )
    .unwrap();
    let jobs = spec.expand().unwrap();
    assert_eq!(jobs.len(), 4);
    assert_eq!(jobs[0].cfg.attack, AttackKind::Ipm { eps: 0.5 });
    assert_eq!(jobs[2].cfg.attack, AttackKind::Gaussian { std: 10.0 });
    assert_eq!(jobs[0].stall_prob, 0.0);
    assert_eq!(jobs[1].stall_prob, 0.2);
    // ids differ across every coordinate
    let ids: std::collections::BTreeSet<_> = jobs.iter().map(|j| j.id.clone()).collect();
    assert_eq!(ids.len(), 4);
}
