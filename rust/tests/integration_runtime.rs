//! Integration over the PJRT runtime: AOT artifacts (Pallas/JAX → HLO text)
//! must load, execute, and agree with the native Rust oracle bit-for-bit
//! (up to f32 accumulation order).
//!
//! Requires `make artifacts`; tests are skipped (with a note) if the
//! artifact directory is missing so `cargo test` works pre-build.

use lad::coding::{Assignment, TaskMatrix};
use lad::data::linreg::LinRegDataset;
use lad::experiments::common::{run_variant, Variant};
use lad::grad::{CodedGradOracle, NativeLinReg, RuntimeLinReg};
use lad::runtime::{Runtime, TensorIn};
use lad::util::math::{rel_err, Mat};
use lad::util::rng::Rng;

fn artifacts_dir() -> Option<String> {
    let dir = std::env::var("LAD_ARTIFACTS").unwrap_or_else(|_| {
        format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"))
    });
    if std::path::Path::new(&dir).join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping runtime tests: no artifacts at {dir} (run `make artifacts`)");
        None
    }
}

fn linreg_pair(dir: &str, seed: u64) -> Option<(NativeLinReg, RuntimeLinReg, usize, usize)> {
    let rt = Runtime::load(dir).unwrap();
    let meta = &rt.manifest().entries["coded_grad"].meta;
    let (n, q) = (meta["n"] as usize, meta["q"] as usize);
    let mut rng = Rng::new(seed);
    let ds = LinRegDataset::generate(n, q, 0.3, &mut rng);
    let native = NativeLinReg::new(ds.clone());
    let runtime = RuntimeLinReg::new(rt, ds).unwrap();
    Some((native, runtime, n, q))
}

#[test]
fn coded_grad_parity_native_vs_pjrt() {
    let Some(dir) = artifacts_dir() else { return };
    let (mut native, mut runtime, n, q) = linreg_pair(&dir, 21).unwrap();
    let mut rng = Rng::new(22);
    for d in [1usize, 5, 20] {
        let s = TaskMatrix::cyclic(n, d);
        let assign = Assignment::draw(n, &mut rng);
        let subsets: Vec<Vec<usize>> =
            (0..n).map(|i| assign.subsets_for(s.row(assign.tasks[i])).collect()).collect();
        let x = rng.gauss_vec(q);
        let mut a = Mat::zeros(n, q);
        let mut b = Mat::zeros(n, q);
        native.coded_grads(&x, &subsets, &mut a).unwrap();
        runtime.coded_grads(&x, &subsets, &mut b).unwrap();
        let err = rel_err(&b.data, &a.data);
        assert!(err < 1e-5, "d={d}: parity err {err}");
    }
}

#[test]
fn loss_and_grad_matrix_parity() {
    let Some(dir) = artifacts_dir() else { return };
    let (mut native, mut runtime, n, q) = linreg_pair(&dir, 23).unwrap();
    let mut rng = Rng::new(24);
    let x = rng.gauss_vec(q);
    let ln = native.loss(&x).unwrap();
    let lr = runtime.loss(&x).unwrap();
    assert!((ln - lr).abs() / ln.max(1.0) < 1e-5, "loss {ln} vs {lr}");
    let mut ga = Mat::zeros(n, q);
    let mut gb = Mat::zeros(n, q);
    native.grad_matrix(&x, &mut ga).unwrap();
    runtime.grad_matrix(&x, &mut gb).unwrap();
    assert!(rel_err(&gb.data, &ga.data) < 1e-5);
}

#[test]
fn full_training_run_on_pjrt_oracle_matches_native() {
    let Some(dir) = artifacts_dir() else { return };
    std::env::set_var("LAD_ARTIFACTS", &dir);
    let rt = Runtime::load(&dir).unwrap();
    let meta = &rt.manifest().entries["coded_grad"].meta;
    let (n, q) = (meta["n"] as usize, meta["q"] as usize);
    drop(rt);
    let mut rng = Rng::new(31);
    let ds = LinRegDataset::generate(n, q, 0.3, &mut rng);
    let mut cfg = lad::config::TrainConfig::default();
    cfg.n_devices = n;
    cfg.n_honest = n * 4 / 5;
    cfg.dim = q;
    cfg.d = 5;
    cfg.iters = 30;
    cfg.lr = 3e-5;
    cfg.log_every = 10;
    let mut native_cfg = cfg.clone();
    native_cfg.oracle = lad::config::OracleKind::NativeLinreg;
    let mut rt_cfg = cfg.clone();
    rt_cfg.oracle = lad::config::OracleKind::RuntimeLinreg;
    let a = run_variant(&ds, &Variant { label: "n".into(), cfg: native_cfg, draco_r: None }, 32)
        .unwrap();
    let b = run_variant(&ds, &Variant { label: "r".into(), cfg: rt_cfg, draco_r: None }, 32)
        .unwrap();
    let rel = (a.final_loss - b.final_loss).abs() / a.final_loss.max(1e-9);
    assert!(rel < 1e-3, "native {} vs pjrt {}", a.final_loss, b.final_loss);
}

#[test]
fn transformer_artifacts_execute_and_losses_are_sane() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::load(&dir).unwrap();
    if !rt.has("transformer_grad") {
        eprintln!("skipping: transformer artifacts not built");
        return;
    }
    let meta = rt.manifest().entries["transformer_grad"].meta.clone();
    let p = meta["params"] as usize;
    let vocab = meta["vocab"] as usize;
    let (batch, seq) = (meta["batch"] as usize, meta["seq"] as usize);
    // init from the artifact
    let theta = rt
        .exec_f32("transformer_init", &[TensorIn::I32(&[7], &[])])
        .unwrap()
        .remove(0);
    assert_eq!(theta.len(), p);
    assert!(theta.iter().all(|x| x.is_finite()));
    // loss at init ≈ ln(vocab)
    let mut rng = Rng::new(41);
    let windows: Vec<i32> =
        (0..batch * (seq + 1)).map(|_| rng.below(vocab) as i32).collect();
    let outs = rt
        .exec_f32(
            "transformer_grad",
            &[
                TensorIn::F32(&theta, &[p as i64]),
                TensorIn::I32(&windows, &[batch as i64, seq as i64 + 1]),
            ],
        )
        .unwrap();
    let loss = outs[0][0] as f64;
    let grad = &outs[1];
    assert!((loss - (vocab as f64).ln()).abs() < 1.0, "init loss {loss}");
    assert_eq!(grad.len(), p);
    assert!(grad.iter().all(|x| x.is_finite()));
    // a gradient step on the same batch must reduce the loss
    let theta2: Vec<f32> = theta.iter().zip(grad).map(|(t, g)| t - 0.5 * g).collect();
    let outs2 = rt
        .exec_f32(
            "transformer_loss",
            &[
                TensorIn::F32(&theta2, &[p as i64]),
                TensorIn::I32(&windows, &[batch as i64, seq as i64 + 1]),
            ],
        )
        .unwrap();
    assert!((outs2[0][0] as f64) < loss, "step did not reduce loss");
}

#[test]
fn executable_cache_hits_after_first_call() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::load(&dir).unwrap();
    let meta = &rt.manifest().entries["linreg_loss"].meta;
    let (n, q) = (meta["n"] as usize, meta["q"] as usize);
    let mut rng = Rng::new(51);
    let x = rng.gauss_vec(q);
    let z = rng.gauss_vec(n * q);
    let y = rng.gauss_vec(n);
    for _ in 0..3 {
        rt.exec_f32(
            "linreg_loss",
            &[
                TensorIn::F32(&x, &[q as i64]),
                TensorIn::F32(&z, &[n as i64, q as i64]),
                TensorIn::F32(&y, &[n as i64]),
            ],
        )
        .unwrap();
    }
    assert_eq!(rt.stats.compiles, 1, "must compile exactly once");
    assert_eq!(rt.stats.executes, 3);
}

#[test]
fn shape_mismatch_is_rejected_before_execution() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::load(&dir).unwrap();
    let bad = vec![0.0f32; 3];
    let err = rt.exec_f32("linreg_loss", &[TensorIn::F32(&bad, &[3])]).unwrap_err();
    assert!(format!("{err}").contains("inputs"), "{err}");
}
