//! Seed-stream audit (ROADMAP item): randomized configurations pushed
//! through the full LAD / Com-LAD loop must stay **bit-identical** between
//! serial and parallel execution — and, per the `util::math` lane contract,
//! between every compiled kernel tier (scalar / SSE2 / AVX2+FMA; build with
//! `--features simd` to exercise the intrinsics ladder; the scalar
//! reference is always compiled for comparison). The training-trace fuzz
//! runs under whatever tier the dispatcher selected, so the CI matrix legs
//! that pin `LAD_SIMD_TIER` turn it into a per-tier end-to-end pin; the
//! kernel-level fuzz below additionally compares every detected tier
//! in-process.
//!
//! Unlike `parallel_determinism.rs` (a few hand-picked large configs), this
//! fuzzes the corner lattice: tiny families below every parallelism gate,
//! families straddling the gates, ragged tile edges, packed-triangular
//! row adapters, every aggregator with a parallel pass, stochastic
//! compressors on pre-split streams — including the error-feedback
//! (`ef-*`) compressors' residual carry and the stateful momentum-filter
//! rule, whose traces must be just as thread/tier invariant. The same
//! lattice also pins the elasticity path: a leader killed at a fuzzed
//! iteration and warm-restarted from its checkpoint must match the
//! uninterrupted run bit-for-bit, pipeline on or off.

use lad::aggregation::gram::PairwiseDistances;
use lad::config::{AggregatorKind, AttackKind, CompressionKind, TrainConfig};
use lad::data::linreg::LinRegDataset;
use lad::experiments::common::{run_variant, Variant};
use lad::proptest_lite::{ensure, forall, gen};
use lad::server::TrainTrace;
use lad::util::math::{self, norm_sq, Tier};
use lad::util::parallel::{Parallelism, Pool};
use lad::util::rng::Rng;

#[derive(Debug)]
struct Case {
    n: usize,
    q: usize,
    d: usize,
    f: usize,
    threads: usize,
    agg: AggregatorKind,
    nnm: bool,
    comp: CompressionKind,
    attack: AttackKind,
}

fn gen_case(rng: &mut Rng) -> Case {
    let n = gen::usize_in(rng, 6, 20);
    let q = gen::usize_in(rng, 4, 96);
    let aggs = [
        AggregatorKind::Cwtm,
        AggregatorKind::Median,
        AggregatorKind::Krum,
        AggregatorKind::MultiKrum,
        AggregatorKind::Faba,
        AggregatorKind::Mcc,
        AggregatorKind::GeometricMedian,
        AggregatorKind::MomentumFilter,
    ];
    let comps = [
        CompressionKind::None,
        CompressionKind::RandK { k: gen::usize_in(rng, 1, q) },
        CompressionKind::Qsgd { levels: gen::usize_in(rng, 2, 16) as u32 },
        CompressionKind::EfRandK { k: gen::usize_in(rng, 1, q) },
        CompressionKind::EfQsgd { levels: gen::usize_in(rng, 2, 16) as u32 },
    ];
    let attacks = [
        AttackKind::SignFlip { coeff: -2.0 },
        AttackKind::Alie,
        AttackKind::None,
    ];
    Case {
        n,
        q,
        d: gen::usize_in(rng, 1, n),
        f: rng.below(n / 2),
        threads: [2, 3, 8][rng.below(3)],
        agg: aggs[rng.below(aggs.len())],
        nnm: rng.below(2) == 0,
        comp: comps[rng.below(comps.len())],
        attack: attacks[rng.below(attacks.len())],
    }
}

fn cfg_of(case: &Case, threads: usize) -> TrainConfig {
    let mut cfg = TrainConfig::default();
    cfg.n_devices = case.n;
    cfg.n_honest = case.n - case.f;
    cfg.d = case.d;
    cfg.dim = case.q;
    cfg.iters = 6;
    cfg.lr = 1e-6;
    cfg.sigma_h = 0.3;
    cfg.log_every = 2;
    cfg.aggregator = case.agg;
    cfg.nnm = case.nnm;
    cfg.compression = case.comp;
    cfg.attack = case.attack;
    cfg.threads = threads;
    cfg
}

fn run_case(case: &Case, threads: usize, seed: u64) -> TrainTrace {
    let cfg = cfg_of(case, threads);
    let mut rng = Rng::new(seed);
    let ds = LinRegDataset::generate(cfg.n_devices, cfg.dim, cfg.sigma_h, &mut rng);
    let label = format!("{threads}t");
    run_variant(&ds, &Variant { label, cfg, draco_r: None }, seed ^ 0xF)
        .expect("fuzz case failed to run")
}

fn traces_equal(a: &TrainTrace, b: &TrainTrace) -> Result<(), String> {
    ensure(a.iters == b.iters, || "sampled iterations differ".into())?;
    ensure(a.loss == b.loss, || format!("loss {:?} vs {:?}", a.loss, b.loss))?;
    ensure(a.grad_update_norm == b.grad_update_norm, || "update norms differ".into())?;
    ensure(a.bits == b.bits, || "bit accounting differs".into())?;
    ensure(a.final_loss == b.final_loss, || {
        format!("final loss {} vs {}", a.final_loss, b.final_loss)
    })
}

#[test]
fn fuzzed_training_traces_are_thread_count_invariant() {
    forall(14, 0xA0D1, gen_case, |case| {
        let seed = 0xBEE5 ^ ((case.n as u64) << 8) ^ case.q as u64;
        let serial = run_case(case, 1, seed);
        let par = run_case(case, case.threads, seed);
        traces_equal(&serial, &par)
    });
}

#[test]
fn fuzzed_pairwise_kernel_matches_reference_and_is_schedule_invariant() {
    // sizes chosen to land on both sides of the par gate and on ragged
    // tile edges (TILE = 16): the tiled pass must agree bitwise with the
    // serial triangular pass AND with the direct Gram formula
    forall(
        10,
        0xD15,
        |rng| {
            let n = gen::usize_in(rng, 2, 48);
            let q = gen::usize_in(rng, 1, 160);
            gen::vec_family(rng, n, q, 2.0)
        },
        |msgs| {
            let serial = PairwiseDistances::compute(msgs, &Pool::serial());
            for pool in [Pool::new(4), Pool::scoped(Parallelism::new(3))] {
                let par = PairwiseDistances::compute(msgs, &pool);
                for i in 0..msgs.len() {
                    ensure(serial.row(i).to_vec() == par.row(i).to_vec(), || {
                        format!("row {i} differs under {pool:?}")
                    })?;
                }
            }
            for i in 0..msgs.len() {
                for j in 0..msgs.len() {
                    let want = if i == j {
                        0.0
                    } else {
                        (norm_sq(&msgs[i]) + norm_sq(&msgs[j])
                            - 2.0 * math::dot(&msgs[i], &msgs[j]) as f64)
                            .max(0.0)
                    };
                    ensure(serial.get(i, j) == want, || {
                        format!("entry ({i},{j}): {} vs formula {want}", serial.get(i, j))
                    })?;
                }
            }
            Ok(())
        },
    );
}

#[test]
fn fuzzed_packed_storage_matches_a_full_matrix_reference() {
    // the packed strict-upper-triangle layout + RowView adapter must be
    // indistinguishable from the full symmetric N×N matrix PR 2 stored:
    // build the full reference naively from the same Gram expression and
    // compare every access path (get, row iteration, materialized rows)
    forall(
        12,
        0x9AC,
        |rng| {
            let n = gen::usize_in(rng, 1, 40);
            let q = gen::usize_in(rng, 1, 96);
            gen::vec_family(rng, n, q, 3.0)
        },
        |msgs| {
            let n = msgs.len();
            let pd = PairwiseDistances::compute(msgs, &Pool::new(4));
            ensure(pd.packed_len() == n * n.saturating_sub(1) / 2, || {
                format!("packed len {} for n={n}", pd.packed_len())
            })?;
            // full reference, every (i, j) from the same expression
            let mut full = vec![0.0f64; n * n];
            for i in 0..n {
                for j in i + 1..n {
                    let d = (norm_sq(&msgs[i]) + norm_sq(&msgs[j])
                        - 2.0 * math::dot(&msgs[i], &msgs[j]) as f64)
                        .max(0.0);
                    full[i * n + j] = d;
                    full[j * n + i] = d;
                }
            }
            for i in 0..n {
                let row = pd.row(i).to_vec();
                ensure(row == full[i * n..(i + 1) * n], || format!("row {i} vs full"))?;
                for j in 0..n {
                    ensure(pd.get(i, j) == full[i * n + j], || {
                        format!("get({i},{j}) vs full")
                    })?;
                    ensure(pd.get(i, j) == pd.get(j, i), || format!("symmetry ({i},{j})"))?;
                }
            }
            Ok(())
        },
    );
}

#[test]
fn fuzzed_mixed_gram_matches_a_full_matrix_reference() {
    // The NNM → inner-Krum Gram-reuse path: `PairwiseDistances::mixed`
    // (packed W·G·Wᵀ, the two-pass U = W·G then H = U·Wᵀ evaluation) must
    // agree bit-for-bit with a naive full-matrix reference built from the
    // same recovered-Gram expression — and be pool-width invariant.
    forall(
        10,
        0x316D,
        |rng| {
            let n = gen::usize_in(rng, 2, 40);
            let q = gen::usize_in(rng, 1, 96);
            let msgs = gen::vec_family(rng, n, q, 3.0);
            let m = gen::usize_in(rng, 1, n);
            let sets: Vec<Vec<usize>> = (0..m)
                .map(|_| {
                    let k = gen::usize_in(rng, 1, n);
                    let mut s: Vec<usize> = (0..k).map(|_| rng.below(n)).collect();
                    s.sort_unstable();
                    s.dedup();
                    s
                })
                .collect();
            (msgs, sets)
        },
        |(msgs, sets)| {
            let n = msgs.len();
            let m = sets.len();
            let pd = PairwiseDistances::compute(msgs, &Pool::serial());
            let mixed = pd.mixed(sets, &Pool::serial());
            for pool in [Pool::new(4), Pool::scoped(Parallelism::new(3))] {
                let par = pd.mixed(sets, &pool);
                for i in 0..m {
                    ensure(mixed.row(i).to_vec() == par.row(i).to_vec(), || {
                        format!("mixed row {i} differs under {pool:?}")
                    })?;
                }
            }
            // naive reference: full Gram recovery, full U = W·G, full H,
            // summed in the same (ascending-set) order
            let norms = pd.norms();
            let g =
                |a: usize, b: usize| -> f64 { (norms[a] + norms[b] - pd.get(a, b)) / 2.0 };
            let mut u = vec![vec![0.0f64; n]; m];
            for (i, set) in sets.iter().enumerate() {
                for &a in set {
                    for b in 0..n {
                        u[i][b] += g(a, b);
                    }
                }
            }
            let h = |i: usize, j: usize| -> f64 {
                let mut s = 0.0f64;
                for &b in &sets[j] {
                    s += u[i][b];
                }
                s / (sets[i].len() as f64 * sets[j].len() as f64)
            };
            let hn: Vec<f64> = (0..m).map(|i| h(i, i).max(0.0)).collect();
            for i in 0..m {
                ensure(mixed.norms()[i] == hn[i], || format!("mixed norm {i}"))?;
                for j in 0..m {
                    let want =
                        if i == j { 0.0 } else { (hn[i] + hn[j] - 2.0 * h(i, j)).max(0.0) };
                    ensure(mixed.get(i, j) == want, || {
                        format!("mixed({i},{j}): {} vs naive {want}", mixed.get(i, j))
                    })?;
                }
            }
            Ok(())
        },
    );
}

#[test]
fn fuzzed_pipelined_cluster_traces_match_phase_serial() {
    // The tentpole bit-identity gate: the pipelined leader (shared x-frame
    // broadcast, staged t+1 assignment draw, slab decode) must produce
    // exactly the trace of the legacy phase-serial leader — across thread
    // counts, compressors (incl. ef-*), compression sites, and the
    // deadline-mode gather loop — including wire byte accounting.
    use lad::net::LeaderOpts;
    use lad::server::cluster::{run_cluster_with, ClusterOpts};
    use std::time::Duration;

    let run = |case: &Case, threads: usize, seed: u64, pipeline: bool, deadline: bool,
               dcomp: bool|
     -> TrainTrace {
        let cfg = cfg_of(case, threads);
        let mut rng = Rng::new(seed);
        let ds = LinRegDataset::generate(cfg.n_devices, cfg.dim, cfg.sigma_h, &mut rng);
        let pool = Pool::new(threads);
        let agg = lad::aggregation::from_config_pooled(&cfg, &pool);
        let atk = lad::attack::from_kind(cfg.attack);
        let comp = lad::compress::from_kind(cfg.compression);
        let opts = ClusterOpts {
            leader: LeaderOpts {
                // generous deadline: exercises the timeout-mode gather loop
                // without any miss actually firing, so traces stay exact
                gather_deadline: deadline.then(|| Duration::from_secs(120)),
                device_compression: dcomp,
                pipeline,
                ..Default::default()
            },
            ..Default::default()
        };
        let mut x0 = vec![0.0f32; cfg.dim];
        run_cluster_with(
            &cfg,
            &ds,
            agg.as_ref(),
            atk.as_ref(),
            comp.as_ref(),
            &mut x0,
            "fuzz-pipeline",
            &mut Rng::new(seed ^ 0xF),
            &pool,
            &opts,
        )
        .expect("cluster fuzz case failed to run")
    };
    forall(6, 0x919E, gen_case, |case| {
        let seed = 0xC1A5 ^ ((case.n as u64) << 10) ^ case.q as u64;
        for dcomp in [false, true] {
            let base = run(case, 1, seed, false, false, dcomp);
            for (threads, pipeline, deadline) in [
                (1, true, false),               // pipelined, serial pool
                (case.threads, true, false),    // pipelined, pooled sends
                (case.threads, false, false),   // phase-serial, pooled
                (1, true, true),                // pipelined under a deadline
            ] {
                let t = run(case, threads, seed, pipeline, deadline, dcomp);
                traces_equal(&base, &t).map_err(|e| {
                    format!("{e} (threads={threads} pipeline={pipeline} deadline={deadline} dcomp={dcomp})")
                })?;
                ensure(t.anomalies == base.anomalies, || "anomaly counts differ".into())?;
                ensure(
                    t.wire_up_bytes == base.wire_up_bytes
                        && t.wire_down_bytes == base.wire_down_bytes,
                    || {
                        format!(
                            "wire bytes differ: up {} vs {}, down {} vs {} \
                             (pipeline={pipeline} deadline={deadline} dcomp={dcomp})",
                            t.wire_up_bytes,
                            base.wire_up_bytes,
                            t.wire_down_bytes,
                            base.wire_down_bytes
                        )
                    },
                )?;
            }
        }
        Ok(())
    });
}

#[test]
fn fuzzed_warm_restart_is_bit_identical_across_the_lattice() {
    // The elasticity gate: killing the leader at a fuzzed iteration and
    // warm-restarting from the checkpoint must reproduce the uninterrupted
    // run bit-for-bit — final iterate, trace, anomaly counts, and wire byte
    // accounting — across compressors (incl. the ef-* residual carry),
    // aggregators (incl. the stateful momentum filter), attacks, and the
    // pipelined vs phase-serial leader.
    use lad::net::LeaderOpts;
    use lad::server::cluster::{run_cluster_kill_resume, run_cluster_with, ClusterOpts};

    forall(6, 0xE1A5, gen_case, |case| {
        let seed = 0x5EED ^ ((case.n as u64) << 9) ^ case.q as u64;
        let kill = 1 + case.q as u64 % 4; // cfg_of pins iters = 6; kill + 1 < 6
        for pipeline in [false, true] {
            let cfg = cfg_of(case, case.threads);
            let pool = Pool::new(case.threads);
            let atk = lad::attack::from_kind(cfg.attack);
            let comp = lad::compress::from_kind(cfg.compression);
            let mut rng = Rng::new(seed);
            let ds = LinRegDataset::generate(cfg.n_devices, cfg.dim, cfg.sigma_h, &mut rng);
            let opts = ClusterOpts {
                leader: LeaderOpts { pipeline, ..Default::default() },
                ..Default::default()
            };
            // fresh aggregator per run: the momentum filter carries state
            let agg = lad::aggregation::from_config_pooled(&cfg, &pool);
            let mut x_ref = vec![0.0f32; cfg.dim];
            let reference = run_cluster_with(
                &cfg,
                &ds,
                agg.as_ref(),
                atk.as_ref(),
                comp.as_ref(),
                &mut x_ref,
                "fuzz-elastic",
                &mut Rng::new(seed ^ 0xF),
                &pool,
                &opts,
            )
            .expect("reference run failed");
            let ckpt = std::env::temp_dir().join(format!(
                "lad-fuzz-restart-{}-{seed:x}-{pipeline}.ckpt",
                std::process::id()
            ));
            let agg = lad::aggregation::from_config_pooled(&cfg, &pool);
            let mut x_drill = vec![0.0f32; cfg.dim];
            let drill = run_cluster_kill_resume(
                &cfg,
                &ds,
                agg.as_ref(),
                atk.as_ref(),
                comp.as_ref(),
                &mut x_drill,
                "fuzz-elastic",
                &mut Rng::new(seed ^ 0xF),
                &pool,
                &opts,
                kill,
                &ckpt,
            )
            .expect("kill-resume drill failed");
            let _ = std::fs::remove_file(&ckpt);
            ensure(x_ref == x_drill, || {
                format!("final iterates differ (pipeline={pipeline} kill={kill})")
            })?;
            traces_equal(&reference, &drill)
                .map_err(|e| format!("{e} (pipeline={pipeline} kill={kill})"))?;
            ensure(drill.anomalies == reference.anomalies, || {
                format!("anomaly counts differ (pipeline={pipeline} kill={kill})")
            })?;
            ensure(
                drill.wire_up_bytes == reference.wire_up_bytes
                    && drill.wire_down_bytes == reference.wire_down_bytes,
                || {
                    format!(
                        "wire bytes differ: up {} vs {}, down {} vs {} \
                         (pipeline={pipeline} kill={kill})",
                        drill.wire_up_bytes,
                        reference.wire_up_bytes,
                        drill.wire_down_bytes,
                        reference.wire_down_bytes
                    )
                },
            )?;
        }
        Ok(())
    });
}

#[test]
fn fuzzed_recorder_parity_traces_are_bit_identical() {
    // The observability gate: everything under `lad::obs` is wall-clock
    // telemetry only, so running with a live JSONL recorder (events +
    // metrics + spans + role-draw journaling) must leave the trace, the
    // anomaly accounting, and the wire bytes bit-identical to the
    // recorder-off run — across compressors (incl. ef-*), aggregators,
    // the pipelined vs phase-serial leader, compression sites, and role
    // rotation (which exercises the ByzantineRoleDrawn emission next to
    // the RNG draw it must not perturb).
    use lad::net::LeaderOpts;
    use lad::obs::{JsonlRecorder, Obs};
    use lad::server::cluster::{run_cluster_with, ClusterOpts};

    let run = |case: &Case, seed: u64, pipeline: bool, dcomp: bool, rotate: bool, obs: Obs|
     -> TrainTrace {
        let cfg = cfg_of(case, case.threads);
        let mut rng = Rng::new(seed);
        let ds = LinRegDataset::generate(cfg.n_devices, cfg.dim, cfg.sigma_h, &mut rng);
        let pool = Pool::new(cfg.threads);
        let agg = lad::aggregation::from_config_pooled(&cfg, &pool);
        let atk = lad::attack::from_kind(cfg.attack);
        let comp = lad::compress::from_kind(cfg.compression);
        let opts = ClusterOpts {
            leader: LeaderOpts {
                pipeline,
                device_compression: dcomp,
                rotate_byzantine: rotate,
                obs,
                ..Default::default()
            },
            ..Default::default()
        };
        let mut x0 = vec![0.0f32; cfg.dim];
        run_cluster_with(
            &cfg,
            &ds,
            agg.as_ref(),
            atk.as_ref(),
            comp.as_ref(),
            &mut x0,
            "fuzz-obs",
            &mut Rng::new(seed ^ 0xF),
            &pool,
            &opts,
        )
        .expect("recorder-parity case failed to run")
    };
    forall(5, 0x0B5E, gen_case, |case| {
        let seed = 0x0B57 ^ ((case.n as u64) << 7) ^ case.q as u64;
        for (pipeline, dcomp, rotate) in
            [(false, false, false), (true, false, true), (true, true, false)]
        {
            let off = run(case, seed, pipeline, dcomp, rotate, Obs::off());
            let journal = std::env::temp_dir().join(format!(
                "lad-fuzz-obs-{}-{seed:x}-{pipeline}-{dcomp}-{rotate}.jsonl",
                std::process::id()
            ));
            let obs = Obs::recording(Box::new(
                JsonlRecorder::create(&journal).expect("journal create"),
            ));
            let on = run(case, seed, pipeline, dcomp, rotate, obs.clone());
            obs.finish().expect("journal flush");
            let _ = std::fs::remove_file(&journal);
            traces_equal(&off, &on)
                .map_err(|e| format!("{e} (pipeline={pipeline} dcomp={dcomp} rotate={rotate})"))?;
            ensure(
                off.anomalies == on.anomalies
                    && off.deadline_misses == on.deadline_misses
                    && off.retirements == on.retirements
                    && off.rejoins == on.rejoins,
                || "anomaly accounting differs with the recorder on".into(),
            )?;
            ensure(
                off.wire_up_bytes == on.wire_up_bytes
                    && off.wire_down_bytes == on.wire_down_bytes,
                || {
                    format!(
                        "wire bytes differ with the recorder on: up {} vs {}, down {} vs {}",
                        on.wire_up_bytes,
                        off.wire_up_bytes,
                        on.wire_down_bytes,
                        off.wire_down_bytes
                    )
                },
            )?;
        }
        Ok(())
    });
}

#[test]
fn fuzzed_recorder_parity_covers_checkpoints_and_warm_restart() {
    // Recorder parity through the elasticity path: the kill/warm-restart
    // drill with a live recorder must reproduce the recorder-off drill
    // bit-for-bit — final iterate, trace, AND the checkpoint file bytes
    // (the CheckpointWritten event reads file metadata, it must never
    // touch the file) — and the journal must actually contain the
    // checkpoint cut and the failover with correct attribution.
    use lad::net::LeaderOpts;
    use lad::obs::{Event, JsonlRecorder, Obs};
    use lad::server::cluster::{run_cluster_kill_resume, ClusterOpts};
    use lad::util::json;

    forall(3, 0xC4B0, gen_case, |case| {
        let seed = 0xAB1E ^ ((case.n as u64) << 6) ^ case.q as u64;
        let kill = 1 + case.q as u64 % 4; // cfg_of pins iters = 6; kill + 1 < 6
        let run = |obs: Obs, tag: &str| -> (TrainTrace, Vec<f32>, Vec<u8>) {
            let cfg = cfg_of(case, case.threads);
            let mut rng = Rng::new(seed);
            let ds = LinRegDataset::generate(cfg.n_devices, cfg.dim, cfg.sigma_h, &mut rng);
            let pool = Pool::new(case.threads);
            let agg = lad::aggregation::from_config_pooled(&cfg, &pool);
            let atk = lad::attack::from_kind(cfg.attack);
            let comp = lad::compress::from_kind(cfg.compression);
            let opts = ClusterOpts {
                leader: LeaderOpts { obs, ..Default::default() },
                ..Default::default()
            };
            let ckpt = std::env::temp_dir().join(format!(
                "lad-fuzz-obsckpt-{}-{seed:x}-{tag}.ckpt",
                std::process::id()
            ));
            let mut x0 = vec![0.0f32; cfg.dim];
            let tr = run_cluster_kill_resume(
                &cfg,
                &ds,
                agg.as_ref(),
                atk.as_ref(),
                comp.as_ref(),
                &mut x0,
                "fuzz-obs-elastic",
                &mut Rng::new(seed ^ 0xF),
                &pool,
                &opts,
                kill,
                &ckpt,
            )
            .expect("kill-resume drill failed");
            let bytes = std::fs::read(&ckpt).expect("checkpoint file missing after drill");
            let _ = std::fs::remove_file(&ckpt);
            (tr, x0, bytes)
        };
        let (t_off, x_off, ck_off) = run(Obs::off(), "off");
        let journal = std::env::temp_dir()
            .join(format!("lad-fuzz-obsj-{}-{seed:x}.jsonl", std::process::id()));
        let obs =
            Obs::recording(Box::new(JsonlRecorder::create(&journal).expect("journal create")));
        let (t_on, x_on, ck_on) = run(obs.clone(), "on");
        obs.finish().expect("journal flush");
        let body = std::fs::read_to_string(&journal).expect("journal readable");
        let _ = std::fs::remove_file(&journal);
        traces_equal(&t_off, &t_on).map_err(|e| format!("{e} (kill={kill})"))?;
        ensure(x_off == x_on, || "final iterates differ with the recorder on".into())?;
        ensure(ck_off == ck_on, || "checkpoint bytes differ with the recorder on".into())?;
        let events: Vec<Event> = body
            .lines()
            .filter_map(|l| json::parse(l).ok())
            .filter_map(|j| Event::from_json(&j))
            .collect();
        // halt_after = kill cuts the checkpoint AFTER iteration kill, so
        // both events carry the resume iteration kill + 1
        let resume_iter = kill + 1;
        ensure(
            events.iter().any(
                |e| matches!(e, Event::CheckpointWritten { iter, bytes, .. }
                    if *iter == resume_iter && *bytes == ck_off.len() as u64),
            ),
            || {
                format!(
                    "no checkpoint_written at iter {resume_iter} (size {}): {body}",
                    ck_off.len()
                )
            },
        )?;
        ensure(
            events
                .iter()
                .any(|e| matches!(e, Event::LeaderFailover { iter, .. } if *iter == resume_iter)),
            || format!("no leader_failover at iter {resume_iter}: {body}"),
        )
    });
}

#[test]
fn fuzzed_kernel_tiers_are_bit_identical() {
    // every tier the CPU can run (scalar always; SSE2 + AVX2 under
    // --features simd on capable hosts) must agree with the scalar
    // reference bit-for-bit on random lengths, including remainder paths
    let tiers = math::detected_tiers();
    assert!(tiers.contains(&Tier::Scalar));
    forall(
        24,
        0x71E2,
        |rng| {
            let len = gen::usize_in(rng, 0, 300);
            (gen::vec_f32(rng, len, 8.0), gen::vec_f32(rng, len, 5.0))
        },
        |(a, b)| {
            for &tier in &tiers {
                let n = tier.name();
                ensure(
                    tier.dot(a, b).to_bits() == math::scalar::dot(a, b).to_bits(),
                    || format!("{n} dot mismatch at len {}", a.len()),
                )?;
                ensure(
                    tier.norm_sq(a).to_bits() == math::scalar::norm_sq(a).to_bits(),
                    || format!("{n} norm_sq mismatch at len {}", a.len()),
                )?;
                ensure(
                    tier.dist_sq(a, b).to_bits() == math::scalar::dist_sq(a, b).to_bits(),
                    || format!("{n} dist_sq mismatch at len {}", a.len()),
                )?;
                let mut y1 = b.clone();
                let mut y2 = b.clone();
                tier.axpy(1.618, a, &mut y1);
                math::scalar::axpy(1.618, a, &mut y2);
                ensure(y1 == y2, || format!("{n} axpy mismatch at len {}", a.len()))?;
                let mut x1 = a.clone();
                let mut x2 = a.clone();
                tier.scale(&mut x1, -0.577);
                math::scalar::scale(&mut x2, -0.577);
                ensure(x1 == x2, || format!("{n} scale mismatch at len {}", a.len()))?;
            }
            Ok(())
        },
    );
}

#[test]
fn simd_tier_env_override_is_respected() {
    // the CI matrix legs pin LAD_SIMD_TIER per process; when the variable
    // is set (and the binary compiled the intrinsics tiers), the dispatcher
    // must select exactly min(requested, widest detected)
    let Ok(raw) = std::env::var("LAD_SIMD_TIER") else {
        return; // nothing pinned in this process
    };
    let Some(requested) = Tier::parse(&raw) else {
        return; // malformed request falls back to auto — covered by unit tests
    };
    if !math::SIMD_ACTIVE {
        assert_eq!(math::active_tier(), Tier::Scalar, "non-simd builds are scalar-only");
        return;
    }
    let widest = *math::detected_tiers().last().expect("scalar is always detected");
    let expect = requested.min(widest);
    assert_eq!(
        math::active_tier(),
        expect,
        "LAD_SIMD_TIER={raw} should pin {} (widest {})",
        expect.name(),
        widest.name()
    );
}

#[test]
fn fuzzed_active_math_backend_matches_scalar_reference() {
    // trivially true without --features simd; the CI simd job makes this
    // the scalar-vs-SSE2 lane-contract pin
    forall(
        32,
        0x51D,
        |rng| {
            let len = gen::usize_in(rng, 0, 300);
            (gen::vec_f32(rng, len, 8.0), gen::vec_f32(rng, len, 5.0))
        },
        |(a, b)| {
            ensure(
                math::dot(a, b).to_bits() == math::scalar::dot(a, b).to_bits(),
                || format!("dot mismatch at len {}", a.len()),
            )?;
            ensure(
                math::norm_sq(a).to_bits() == math::scalar::norm_sq(a).to_bits(),
                || format!("norm_sq mismatch at len {}", a.len()),
            )?;
            ensure(
                math::dist_sq(a, b).to_bits() == math::scalar::dist_sq(a, b).to_bits(),
                || format!("dist_sq mismatch at len {}", a.len()),
            )?;
            let mut y1 = b.clone();
            let mut y2 = b.clone();
            math::axpy(1.618, a, &mut y1);
            math::scalar::axpy(1.618, a, &mut y2);
            ensure(y1 == y2, || format!("axpy mismatch at len {}", a.len()))?;
            let mut x1 = a.clone();
            let mut x2 = a.clone();
            math::scale(&mut x1, -0.577);
            math::scalar::scale(&mut x2, -0.577);
            ensure(x1 == x2, || format!("scale mismatch at len {}", a.len()))
        },
    );
}
