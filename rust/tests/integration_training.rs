//! Integration: the full training loop across coding × aggregation ×
//! attack × compression combinations (native oracle).

use lad::aggregation;
use lad::config::{AggregatorKind, AttackKind, CompressionKind, TrainConfig};
use lad::data::linreg::LinRegDataset;
use lad::experiments::common::{run_variant, Variant};
use lad::util::rng::Rng;

fn base_cfg() -> TrainConfig {
    let mut cfg = TrainConfig::default();
    cfg.n_devices = 30;
    cfg.n_honest = 24;
    cfg.d = 5;
    cfg.dim = 30;
    cfg.iters = 500;
    cfg.lr = 8e-5;
    cfg.sigma_h = 0.3;
    cfg.log_every = 100;
    cfg
}

fn dataset(cfg: &TrainConfig, seed: u64) -> LinRegDataset {
    let mut rng = Rng::new(seed);
    LinRegDataset::generate(cfg.n_devices, cfg.dim, cfg.sigma_h, &mut rng)
}

#[test]
fn every_robust_aggregator_survives_sign_flip() {
    let cfg = base_cfg();
    let ds = dataset(&cfg, 1);
    let init_loss = ds.loss(&vec![0.0; cfg.dim]);
    for kind in [
        AggregatorKind::Cwtm,
        AggregatorKind::Median,
        AggregatorKind::GeometricMedian,
        AggregatorKind::MultiKrum,
        AggregatorKind::Faba,
        AggregatorKind::Mcc,
        AggregatorKind::Tgn,
    ] {
        let mut c = cfg.clone();
        c.aggregator = kind;
        let tr = run_variant(
            &ds,
            &Variant { label: kind.name().into(), cfg: c, draco_r: None },
            2,
        )
        .unwrap();
        assert!(
            tr.final_loss < init_loss * 0.8,
            "{}: {} !< {}",
            kind.name(),
            tr.final_loss,
            init_loss
        );
    }
}

#[test]
fn coding_improves_every_robust_rule() {
    // the meta-algorithm claim: LAD(d) <= plain(d=1) for each rule
    let cfg = base_cfg();
    let ds = dataset(&cfg, 3);
    for kind in [AggregatorKind::Cwtm, AggregatorKind::Median, AggregatorKind::GeometricMedian] {
        let mut plain = cfg.clone();
        plain.d = 1;
        plain.aggregator = kind;
        let mut coded = cfg.clone();
        coded.d = 10;
        coded.aggregator = kind;
        let t1 = run_variant(&ds, &Variant { label: "p".into(), cfg: plain, draco_r: None }, 4)
            .unwrap();
        let t2 = run_variant(&ds, &Variant { label: "c".into(), cfg: coded, draco_r: None }, 4)
            .unwrap();
        assert!(
            t2.final_loss <= t1.final_loss * 1.05,
            "{}: coded {} !<= plain {}",
            kind.name(),
            t2.final_loss,
            t1.final_loss
        );
    }
}

#[test]
fn compressed_training_converges_with_all_unbiased_ops() {
    let mut cfg = base_cfg();
    cfg.lr = 3e-5; // compression noise needs a smaller step
    cfg.iters = 800;
    let ds = dataset(&cfg, 5);
    let init_loss = ds.loss(&vec![0.0; cfg.dim]);
    for comp in [
        CompressionKind::None,
        CompressionKind::RandK { k: 10 },
        CompressionKind::Qsgd { levels: 16 },
    ] {
        let mut c = cfg.clone();
        c.compression = comp;
        let tr = run_variant(
            &ds,
            &Variant { label: comp.name().into(), cfg: c, draco_r: None },
            6,
        )
        .unwrap();
        assert!(
            tr.final_loss < init_loss * 0.9,
            "{}: {} !< {}",
            comp.name(),
            tr.final_loss,
            init_loss
        );
    }
}

#[test]
fn compression_reduces_bits_proportionally() {
    let mut cfg = base_cfg();
    cfg.iters = 50;
    let ds = dataset(&cfg, 7);
    let mut dense_cfg = cfg.clone();
    dense_cfg.compression = CompressionKind::None;
    let mut sparse_cfg = cfg.clone();
    sparse_cfg.compression = CompressionKind::RandK { k: 3 }; // 10% of Q=30
    let dense =
        run_variant(&ds, &Variant { label: "d".into(), cfg: dense_cfg, draco_r: None }, 8).unwrap();
    let sparse =
        run_variant(&ds, &Variant { label: "s".into(), cfg: sparse_cfg, draco_r: None }, 8)
            .unwrap();
    let ratio = sparse.total_bits() as f64 / dense.total_bits() as f64;
    // 3·(32+5) / (30·32) ≈ 0.116
    assert!(ratio < 0.15, "compression ratio {ratio}");
}

#[test]
fn rotating_byzantine_identities_also_converges() {
    use lad::attack::SignFlip;
    use lad::compress::Identity;
    use lad::grad::NativeLinReg;
    use lad::server::trainer::Trainer;
    let cfg = base_cfg();
    let ds = dataset(&cfg, 9);
    let agg = aggregation::from_config(&cfg);
    let attack = SignFlip { coeff: -2.0 };
    let mut trainer = Trainer::new(&cfg, agg.as_ref(), &attack, &Identity);
    trainer.rotate_byzantine = true;
    let mut oracle = NativeLinReg::new(ds.clone());
    let mut x0 = vec![0.0; cfg.dim];
    let tr = trainer.run(&mut oracle, &mut x0, "rotating", &mut Rng::new(10)).unwrap();
    assert!(tr.final_loss < ds.loss(&vec![0.0; cfg.dim]) * 0.8);
}

#[test]
fn stronger_attacks_do_not_break_lad_cwtm_nnm() {
    let mut cfg = base_cfg();
    cfg.nnm = true;
    cfg.d = 10;
    let ds = dataset(&cfg, 11);
    let init_loss = ds.loss(&vec![0.0; cfg.dim]);
    for atk in [
        AttackKind::Alie,
        AttackKind::Ipm { eps: 0.5 },
        AttackKind::Zero,
        AttackKind::RandomSpike { scale: 1e4 },
        AttackKind::Mimic,
    ] {
        let mut c = cfg.clone();
        c.attack = atk;
        let tr =
            run_variant(&ds, &Variant { label: atk.name().into(), cfg: c, draco_r: None }, 12)
                .unwrap();
        assert!(
            tr.final_loss < init_loss,
            "{}: {} !< init {}",
            atk.name(),
            tr.final_loss,
            init_loss
        );
    }
}

#[test]
fn trainer_is_deterministic_given_seed() {
    let cfg = base_cfg();
    let ds = dataset(&cfg, 13);
    let v = Variant { label: "det".into(), cfg, draco_r: None };
    let a = run_variant(&ds, &v, 14).unwrap();
    let b = run_variant(&ds, &v, 14).unwrap();
    assert_eq!(a.final_loss, b.final_loss);
    assert_eq!(a.loss, b.loss);
}
