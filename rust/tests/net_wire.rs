//! Property tests for the `net` wire codec and framing: every message and
//! every compressed-payload variant must round-trip bit-exactly, and
//! corrupted frames (flipped bytes, truncations, hostile lengths) must be
//! rejected.

use lad::compress::{Compressor, Identity, Qsgd, RandK, TopK};
use lad::config::CompressionKind;
use lad::data::linreg::LinRegDataset;
use lad::net::frame::{self, FrameError};
use lad::net::wire::{DatasetBlock, Msg, Payload, WIRE_VERSION};
use lad::proptest_lite::{ensure, forall, gen};
use lad::util::rng::Rng;

fn rand_compression(rng: &mut Rng) -> CompressionKind {
    match rng.below(4) {
        0 => CompressionKind::None,
        1 => CompressionKind::RandK { k: gen::usize_in(rng, 1, 64) },
        2 => CompressionKind::TopK { k: gen::usize_in(rng, 1, 64) },
        _ => CompressionKind::Qsgd { levels: gen::usize_in(rng, 1, 1024) as u32 },
    }
}

fn rand_payload(rng: &mut Rng) -> Payload {
    match rng.below(3) {
        0 => Payload::Dense { values: gen::vec_f32(rng, gen::usize_in(rng, 0, 40), 10.0) },
        1 => {
            let dim = gen::usize_in(rng, 1, 50);
            let nnz = gen::usize_in(rng, 0, dim);
            let mut idx: Vec<u32> = (0..dim as u32).collect();
            rng.shuffle(&mut idx);
            idx.truncate(nnz);
            idx.sort_unstable();
            Payload::Sparse { dim: dim as u32, idx, values: gen::vec_f32(rng, nnz, 10.0) }
        }
        _ => {
            let dim = gen::usize_in(rng, 0, 40);
            let levels = gen::usize_in(rng, 1, 64) as u32;
            let lb = (32 - levels.leading_zeros()) as usize;
            let packed = vec![0xA5u8; (dim * (1 + lb)).div_ceil(8)];
            // norm strictly positive: zero-norm payloads carry no packed
            // bytes, a shape the dedicated unit test covers
            Payload::Quantized { dim: dim as u32, levels, norm: rng.f32() * 100.0 + 0.5, packed }
        }
    }
}

fn rand_msg(rng: &mut Rng) -> Msg {
    match rng.below(5) {
        0 => Msg::Join {
            version: rng.below(256) as u8,
            device: rng.below(10_000) as u32,
            digest: rng.next_u64(),
        },
        1 => {
            let dataset = if rng.bernoulli(0.5) {
                let n = gen::usize_in(rng, 1, 6);
                let q = gen::usize_in(rng, 1, 5);
                let ds = LinRegDataset::generate(n, q, rng.f64(), rng);
                Some(DatasetBlock::from_dataset(&ds))
            } else {
                None
            };
            Msg::Hello {
                version: WIRE_VERSION,
                device: rng.below(100) as u32,
                n_devices: rng.below(1000) as u32,
                dim: rng.below(1000) as u32,
                byzantine: rng.bernoulli(0.5),
                device_compression: rng.bernoulli(0.5),
                comp_seed: rng.next_u64(),
                digest: rng.next_u64(),
                compression: rand_compression(rng),
                dataset,
            }
        }
        2 => Msg::Broadcast {
            iter: rng.below(1 << 20) as u32,
            x: gen::vec_f32(rng, gen::usize_in(rng, 1, 60), 100.0),
            subsets: (0..gen::usize_in(rng, 1, 12)).map(|_| rng.below(64) as u32).collect(),
        },
        3 => Msg::Upload {
            iter: rng.below(1 << 20) as u32,
            device: rng.below(100) as u32,
            analytic_bits: rng.next_u64() >> 20,
            payload: rand_payload(rng),
        },
        _ => Msg::Shutdown,
    }
}

#[test]
fn every_message_type_round_trips() {
    forall(400, 0xA11CE, rand_msg, |msg| {
        let decoded = Msg::decode(&msg.encode()).map_err(|e| format!("{e:#}"))?;
        ensure(&decoded == msg, || format!("round trip changed the message: {decoded:?}"))
    });
}

#[test]
fn every_compressed_variant_reconstructs_bit_exactly() {
    forall(
        200,
        0xB0B,
        |rng| {
            let q = gen::usize_in(rng, 1, 96);
            let scale = [0.01f32, 1.0, 1e4][rng.below(3)];
            let mut g = gen::vec_f32(rng, q, scale);
            if rng.bernoulli(0.1) {
                g = vec![0.0; q]; // degenerate all-zero gradient
            }
            let which = rng.below(4);
            (g, which, gen::usize_in(rng, 1, 96), gen::usize_in(rng, 1, 4096) as u32)
        },
        |(g, which, k, levels)| {
            let comp: Box<dyn Compressor> = match which {
                0 => Box::new(Identity),
                1 => Box::new(RandK::new(*k)),
                2 => Box::new(TopK::new(*k)),
                _ => Box::new(Qsgd::new(*levels)),
            };
            let mut crng = Rng::new(7 ^ *which as u64);
            let c = comp.compress(g, &mut crng);
            let payload = Payload::from_compressed(&c);
            // and through the full message codec, as the worker sends it
            let msg = Msg::Upload {
                iter: 0,
                device: 0,
                analytic_bits: c.bits as u64,
                payload,
            };
            let Msg::Upload { payload: back, .. } =
                Msg::decode(&msg.encode()).map_err(|e| format!("{e:#}"))?
            else {
                return Err("decoded to a different message type".into());
            };
            let dense = back.to_dense().map_err(|e| format!("{e:#}"))?;
            ensure(dense.len() == c.vec.len(), || "dim changed".into())?;
            for (j, (a, b)) in dense.iter().zip(&c.vec).enumerate() {
                ensure(a.to_bits() == b.to_bits(), || {
                    format!("{}: coord {j} changed {b} -> {a}", comp.name())
                })?;
            }
            Ok(())
        },
    );
}

#[test]
fn quantized_payload_is_near_analytic_size() {
    // the point of the variant encodings: wire bytes track the operator's
    // bit accounting instead of dense f32 freight
    let mut rng = Rng::new(5);
    let g: Vec<f32> = (0..512).map(|i| ((i as f32) * 0.11).cos() * 3.0).collect();
    let comp = Qsgd::new(16);
    let c = comp.compress(&g, &mut rng);
    let p = Payload::from_compressed(&c);
    assert!(matches!(p, Payload::Quantized { .. }));
    // payload ≤ analytic bits/8 + fixed header slack
    assert!(
        p.encoded_len() as u64 <= c.bits as u64 / 8 + 16,
        "quantized payload {}B vs analytic {}b",
        p.encoded_len(),
        c.bits
    );
}

#[test]
fn corrupted_frames_are_rejected() {
    forall(
        150,
        0xC0DE,
        |rng| {
            let msg = rand_msg(rng);
            let framed = frame::encode_frame(&msg.encode());
            let pos = gen::usize_in(rng, 0, framed.len() - 1);
            let bit = 1u8 << rng.below(8);
            (framed, pos, bit)
        },
        |(framed, pos, bit)| {
            let mut bad = framed.clone();
            bad[*pos] ^= *bit;
            // any single-bit corruption must fail framing or change the
            // decoded message — silent identical decode is the only bug
            match frame::decode_frame(&bad) {
                Err(_) => Ok(()),
                Ok(payload) => {
                    let orig = frame::decode_frame(framed).expect("original frame valid");
                    ensure(payload != orig, || {
                        format!("flip at {pos} decoded identically")
                    })
                }
            }
        },
    );
}

#[test]
fn truncated_and_oversized_frames_are_rejected() {
    let msg = Msg::Broadcast { iter: 1, x: vec![1.0; 32], subsets: vec![0, 1, 2] };
    let framed = frame::encode_frame(&msg.encode());
    for cut in [0, 3, frame::HEADER_LEN, framed.len() - 1] {
        let mut cursor = &framed[..cut];
        let got = frame::read_frame(&mut cursor, frame::MAX_PAYLOAD);
        assert!(matches!(&got, Err(FrameError::Truncated)), "cut at {cut} accepted: {got:?}");
    }
    // hostile length: rejected before any payload allocation
    let mut hostile = Vec::new();
    hostile.extend_from_slice(&(u32::MAX).to_le_bytes());
    hostile.extend_from_slice(&[0; 4]);
    let mut cursor = &hostile[..];
    assert!(matches!(
        frame::read_frame(&mut cursor, frame::MAX_PAYLOAD),
        Err(FrameError::Oversized { .. })
    ));
}

#[test]
fn decoder_rejects_hostile_reconstruction_dims() {
    // a tiny, CRC-valid frame must not be able to claim a multi-GiB
    // reconstruction: dim is capped at decode time, before to_dense
    let hostile = Msg::Upload {
        iter: 0,
        device: 0,
        analytic_bits: 0,
        payload: Payload::Sparse { dim: u32::MAX, idx: Vec::new(), values: Vec::new() },
    };
    assert!(Msg::decode(&hostile.encode()).is_err());
    let hostile_q = Msg::Upload {
        iter: 0,
        device: 0,
        analytic_bits: 0,
        payload: Payload::Quantized { dim: u32::MAX, levels: 1, norm: 1.0, packed: Vec::new() },
    };
    assert!(Msg::decode(&hostile_q.encode()).is_err());
}

#[test]
fn decoder_rejects_lying_length_prefixes() {
    // a Broadcast whose x-length claims more floats than the buffer holds
    let msg = Msg::Broadcast { iter: 0, x: vec![1.0; 4], subsets: vec![1] };
    let mut enc = msg.encode();
    // x length prefix sits right after tag(1) + iter(4)
    enc[5..9].copy_from_slice(&(u32::MAX).to_le_bytes());
    assert!(Msg::decode(&enc).is_err());
}
