//! Theory ↔ experiment cross-checks: the closed-form constants of §VI
//! against Monte-Carlo measurements from the actual implementation.

use lad::coding::task_matrix::TaskMatrix;
use lad::coding::{encode_coded, Assignment};
use lad::data::linreg::LinRegDataset;
use lad::theory::TheoryParams;
use lad::util::math::{dist_sq, Mat};
use lad::util::rng::Rng;

/// Lemma 2: E‖g_i − μ‖² ≤ (N−d)/(d(N−1)) β², with β² the empirical
/// heterogeneity of the dataset at the evaluation point.
#[test]
fn lemma2_coded_variance_bound_holds_empirically() {
    let (n, q) = (20usize, 12usize);
    let mut rng = Rng::new(91);
    let ds = LinRegDataset::generate(n, q, 0.5, &mut rng);
    let x = rng.gauss_vec(q);
    let mut g = Mat::zeros(n, q);
    ds.grad_matrix(&x, &mut g);
    let beta_sq = ds.heterogeneity_at(&x);
    let mu: Vec<f32> = (0..q)
        .map(|j| (0..n).map(|k| g.row(k)[j]).sum::<f32>() / n as f32)
        .collect();
    for d in [2usize, 5, 10, 19] {
        let s = TaskMatrix::cyclic(n, d);
        let trials = 4000;
        let mut acc = 0.0;
        for _ in 0..trials {
            let assign = Assignment::draw(n, &mut rng);
            let coded = encode_coded(&g, s.row(assign.tasks[0]), &assign);
            acc += dist_sq(&coded, &mu);
        }
        let measured = acc / trials as f64;
        let bound = (n - d) as f64 / (d as f64 * (n - 1) as f64) * beta_sq;
        assert!(
            measured <= bound * 1.1 + 1e-9,
            "d={d}: measured {measured} > bound {bound}"
        );
        // and the bound is reasonably tight (within 3x)
        assert!(measured * 3.0 > bound * 0.9, "d={d}: bound too loose? {measured} vs {bound}");
    }
}

/// The d = N special case: coded messages are exactly μ (variance 0).
#[test]
fn lemma2_d_equals_n_is_exact() {
    let (n, q) = (12usize, 8usize);
    let mut rng = Rng::new(92);
    let ds = LinRegDataset::generate(n, q, 0.3, &mut rng);
    let x = rng.gauss_vec(q);
    let mut g = Mat::zeros(n, q);
    ds.grad_matrix(&x, &mut g);
    let mu: Vec<f32> = (0..q)
        .map(|j| (0..n).map(|k| g.row(k)[j]).sum::<f32>() / n as f32)
        .collect();
    let s = TaskMatrix::cyclic(n, n);
    let assign = Assignment::draw(n, &mut rng);
    for i in 0..n {
        let coded = encode_coded(&g, s.row(assign.tasks[i]), &assign);
        assert!(dist_sq(&coded, &mu) < 1e-6);
    }
}

/// Theory: the error-term ordering ε(d=1) > ε(d=10) > ε(d=N) and the
/// crossover-vs-baseline threshold from the paper's worked example.
#[test]
fn error_term_orderings() {
    let mk = |d: usize| {
        TheoryParams::new(100, 65, d).with_kappa(1.5).with_beta(1.0)
    };
    assert!(mk(1).error_term_lad_bigo() > mk(10).error_term_lad_bigo());
    assert!(mk(10).error_term_lad_bigo() > mk(99).error_term_lad_bigo());
    // paper: LAD beats O(β²κ) baseline from d ≥ 3 at N=100,H=65,κ=1.5
    assert!(mk(2).error_term_lad_bigo() > mk(2).error_term_baseline());
    assert!(mk(3).error_term_lad_bigo() <= mk(3).error_term_baseline());
}

/// Empirical κ of CWTM feeds the theory and predicts a finite error term.
#[test]
fn measured_kappa_gives_finite_bound() {
    use lad::aggregation::{kappa::estimate_kappa, Cwtm};
    let mut rng = Rng::new(93);
    let k = estimate_kappa(&Cwtm::new(0.1), 16, 4, 10, 30, &mut rng);
    assert!(k.is_finite() && k > 0.0);
    let p = TheoryParams::new(20, 16, 10).with_kappa(k).with_beta(1.0);
    let e = p.error_term_lad_bigo();
    assert!(e.is_finite() && e > 0.0);
}

/// Assumption-2 scaling: empirical β² grows roughly linearly in σ_H.
#[test]
fn heterogeneity_scales_with_sigma() {
    let mut prev = 0.0;
    for (i, sigma) in [0.0f64, 0.25, 0.5, 1.0].iter().enumerate() {
        let mut rng = Rng::new(100 + i as u64);
        let ds = LinRegDataset::generate(40, 20, *sigma, &mut rng);
        let b = ds.heterogeneity_at(&vec![0.0; 20]);
        assert!(b >= prev * 0.7, "σ={sigma}: β²={b} vs prev {prev}");
        prev = b;
    }
}
