//! Property tests on the κ-robust aggregation rules (Definition 1
//! invariants) via the proptest_lite harness.

use lad::aggregation::{
    kappa::estimate_kappa, momentum_filter::DEFAULT_ALPHA, Aggregator, CoordinateMedian, Cwtm,
    Faba, GeometricMedian, Krum, Mcc, Mean, MomentumFilter, MultiKrum, Nnm, Tgn,
};
use lad::proptest_lite::{ensure, forall, gen};
use lad::theory::TheoryParams;
use lad::util::math::{dist_sq, mean_of, norm};
use lad::util::rng::Rng;

// MomentumFilter is deliberately NOT in `all_rules`: it carries per-device
// momentum across `aggregate` calls, and the harness above reuses one
// instance per case (the permutation test aggregates twice) — its
// properties are pinned below with a fresh instance per call instead.

fn all_rules(f: usize) -> Vec<Box<dyn Aggregator>> {
    vec![
        Box::new(Mean),
        Box::new(Cwtm::new(0.2)),
        Box::new(CoordinateMedian),
        Box::new(GeometricMedian::default()),
        Box::new(Krum::new(f)),
        Box::new(MultiKrum::new(f)),
        Box::new(Mcc::default()),
        Box::new(Faba::new(f)),
        Box::new(Tgn::new(0.2)),
        Box::new(Nnm::new(f, Box::new(Cwtm::new(0.2)))),
    ]
}

/// Agreement: if every device sends the same vector, every rule returns it.
#[test]
fn prop_agreement() {
    forall(
        60,
        0xA1,
        |rng: &mut Rng| {
            let q = gen::usize_in(rng, 1, 24);
            let n = gen::usize_in(rng, 4, 20);
            (gen::vec_f32(rng, q, 10.0), n)
        },
        |(v, n)| {
            for rule in all_rules(n / 4) {
                let out = rule.aggregate(&vec![v.clone(); *n]);
                let d = dist_sq(&out, v);
                ensure(d < 1e-6, || format!("{}: agreement broken, d={d}", rule.name()))?;
            }
            Ok(())
        },
    );
}

/// Permutation invariance: message order must not matter.
#[test]
fn prop_permutation_invariance() {
    forall(
        40,
        0xA2,
        |rng: &mut Rng| {
            let n = gen::usize_in(rng, 5, 14);
            let q = gen::usize_in(rng, 2, 12);
            let fam = gen::vec_family(rng, n, q, 3.0);
            let perm = rng.permutation(n);
            (fam, perm)
        },
        |(fam, perm)| {
            let shuffled: Vec<Vec<f32>> = perm.iter().map(|&i| fam[i].clone()).collect();
            for rule in all_rules(fam.len() / 4) {
                let a = rule.aggregate(fam);
                let b = rule.aggregate(&shuffled);
                let d = dist_sq(&a, &b);
                ensure(d < 1e-4, || {
                    format!("{}: permutation changed output by {d}", rule.name())
                })?;
            }
            Ok(())
        },
    );
}

/// Translation equivariance for the coordinate-wise rules:
/// agg({x_i + c}) = agg({x_i}) + c.
#[test]
fn prop_translation_equivariance() {
    forall(
        40,
        0xA3,
        |rng: &mut Rng| {
            let n = gen::usize_in(rng, 5, 12);
            let q = gen::usize_in(rng, 2, 10);
            let fam = gen::vec_family(rng, n, q, 2.0);
            let shift = gen::vec_f32(rng, q, 5.0);
            (fam, shift)
        },
        |(fam, shift)| {
            let rules: Vec<Box<dyn Aggregator>> = vec![
                Box::new(Mean),
                Box::new(Cwtm::new(0.2)),
                Box::new(CoordinateMedian),
            ];
            let shifted: Vec<Vec<f32>> = fam
                .iter()
                .map(|v| v.iter().zip(shift).map(|(a, b)| a + b).collect())
                .collect();
            for rule in rules {
                let a = rule.aggregate(fam);
                let b = rule.aggregate(&shifted);
                let back: Vec<f32> = b.iter().zip(shift).map(|(x, s)| x - s).collect();
                let d = dist_sq(&a, &back);
                ensure(d < 1e-3, || format!("{}: not translation-equivariant ({d})", rule.name()))?;
            }
            Ok(())
        },
    );
}

/// Bounded deviation (κ-robustness shape): for robust rules, the output
/// stays within the honest hull scale even under adversarial outliers.
#[test]
fn prop_bounded_deviation_under_outliers() {
    forall(
        40,
        0xA4,
        |rng: &mut Rng| {
            let h = gen::usize_in(rng, 7, 14);
            let f = gen::usize_in(rng, 1, (h - 1) / 2);
            let q = gen::usize_in(rng, 2, 10);
            let honest = gen::vec_family(rng, h, q, 1.0);
            let scale = 10f32.powi(gen::usize_in(rng, 1, 4) as i32);
            (honest, f, scale)
        },
        |(honest, f, scale)| {
            let q = honest[0].len();
            let zbar = mean_of(&honest.iter().map(|v| v.as_slice()).collect::<Vec<_>>());
            let spread: f64 = honest.iter().map(|z| dist_sq(z, &zbar)).sum::<f64>()
                / honest.len() as f64;
            let mut msgs = honest.clone();
            for _ in 0..*f {
                msgs.push(vec![*scale; q]);
            }
            // robust rules only (mean is unbounded by design); CWTM's trim
            // count must cover f — robustness needs ⌊βN⌋ ≥ f (Yin et al.)
            let n = honest.len() + f;
            let beta = ((*f as f64 + 1.0) / n as f64).min(0.49);
            let rules: Vec<Box<dyn Aggregator>> = vec![
                Box::new(Cwtm::new(beta)),
                Box::new(CoordinateMedian),
                Box::new(GeometricMedian::default()),
                Box::new(Krum::new(*f)),
                Box::new(Faba::new(*f)),
            ];
            for rule in rules {
                let out = rule.aggregate(&msgs);
                let dev = dist_sq(&out, &zbar);
                // generous κ bound: deviation ≤ 100 × honest spread + eps
                ensure(dev <= 100.0 * spread + 1e-6, || {
                    format!(
                        "{}: deviation {dev} vs spread {spread} (scale {scale})",
                        rule.name()
                    )
                })?;
            }
            Ok(())
        },
    );
}

/// NNM mixing never increases the honest-family variance.
#[test]
fn prop_nnm_contracts_variance() {
    forall(
        40,
        0xA5,
        |rng: &mut Rng| {
            let n = gen::usize_in(rng, 6, 16);
            let q = gen::usize_in(rng, 2, 8);
            let f = gen::usize_in(rng, 0, n / 3);
            (gen::vec_family(rng, n, q, 4.0), f)
        },
        |(fam, f)| {
            let nnm = Nnm::new(*f, Box::new(Mean));
            let mixed = nnm.mix(fam);
            let var = |xs: &[Vec<f32>]| {
                let mu = mean_of(&xs.iter().map(|v| v.as_slice()).collect::<Vec<_>>());
                xs.iter().map(|x| dist_sq(x, &mu)).sum::<f64>() / xs.len() as f64
            };
            ensure(var(&mixed) <= var(fam) * (1.0 + 1e-6) + 1e-9, || {
                format!("variance grew: {} -> {}", var(fam), var(&mixed))
            })
        },
    );
}

/// Momentum-filter device-permutation equivariance: with fresh (empty)
/// buffers, permuting the device family permutes the momenta with it, so
/// the filtered aggregate is unchanged (up to f32 summation-order noise in
/// the kept-set mean; the kept *set* itself is order-free because scoring
/// ties break by index only on exact f64 score equality).
#[test]
fn prop_momentum_filter_fresh_permutation_invariance() {
    forall(
        40,
        0xA7,
        |rng: &mut Rng| {
            let n = gen::usize_in(rng, 5, 14);
            let q = gen::usize_in(rng, 2, 12);
            let fam = gen::vec_family(rng, n, q, 3.0);
            let perm = rng.permutation(n);
            (fam, perm)
        },
        |(fam, perm)| {
            let shuffled: Vec<Vec<f32>> = perm.iter().map(|&i| fam[i].clone()).collect();
            let f = fam.len() / 4;
            let a = MomentumFilter::new(f, DEFAULT_ALPHA).aggregate(fam);
            let b = MomentumFilter::new(f, DEFAULT_ALPHA).aggregate(&shuffled);
            let d = dist_sq(&a, &b);
            ensure(d < 1e-4, || format!("momentum-filter: permutation moved output by {d}"))
        },
    );
}

/// With f = 0 and fresh buffers, momentum-filter *is* the mean, bitwise:
/// the first observation initializes every momentum to the message itself,
/// nothing is filtered, and the kept-set average runs in the same index
/// order (axpy then scale) as [`Mean`].
#[test]
fn prop_momentum_filter_f0_fresh_is_bitwise_mean() {
    forall(
        40,
        0xA8,
        |rng: &mut Rng| {
            let n = gen::usize_in(rng, 3, 16);
            let q = gen::usize_in(rng, 1, 12);
            gen::vec_family(rng, n, q, 5.0)
        },
        |fam| {
            let a = MomentumFilter::new(0, DEFAULT_ALPHA).aggregate(fam);
            let b = Mean.aggregate(fam);
            for j in 0..a.len() {
                ensure(a[j].to_bits() == b[j].to_bits(), || {
                    format!("coord {j}: momentum-filter {} != mean {}", a[j], b[j])
                })?;
            }
            Ok(())
        },
    );
}

/// κ-robustness sanity on small N: against the `estimate_kappa` adversarial
/// portfolio (state reset between trials, so each trial starts from fresh
/// momenta), momentum-filter's κ̂ stays bounded like the other robust
/// rules, and the measured κ̂ keeps the Theorem-1 convergence condition
/// √(κκ₂) < 1/N satisfiable at d = N−1 in the `theory` closed forms.
#[test]
fn momentum_filter_kappa_bounded_on_small_n() {
    let mut rng = Rng::new(0xA9);
    let (h, f) = (8usize, 2usize);
    let mf = MomentumFilter::new(f, DEFAULT_ALPHA);
    let mut kappa: f64 = 0.0;
    for _ in 0..20 {
        mf.reset();
        kappa = kappa.max(estimate_kappa(&mf, h, f, 5, 1, &mut rng));
    }
    assert!(kappa.is_finite() && kappa >= 0.0, "κ̂ = {kappa}");
    assert!(kappa < 60.0, "momentum-filter κ̂ = {kappa}: not bounded like a robust rule");
    let p = TheoryParams::new(h + f, h, h + f - 1).with_kappa(kappa.max(0.1));
    assert!(p.converges(), "measured κ̂ = {kappa} breaks √(κκ₂) < 1/N at d = N−1");
}

/// Output is always finite for finite inputs.
#[test]
fn prop_finite_output() {
    forall(
        40,
        0xA6,
        |rng: &mut Rng| {
            let n = gen::usize_in(rng, 4, 12);
            let q = gen::usize_in(rng, 1, 8);
            gen::vec_family(rng, n, q, 1e6)
        },
        |fam| {
            for rule in all_rules(fam.len() / 3) {
                let out = rule.aggregate(fam);
                ensure(out.iter().all(|x| x.is_finite()), || {
                    format!("{}: non-finite output {:?} (norm {})", rule.name(), out, norm(&out))
                })?;
            }
            Ok(())
        },
    );
}
