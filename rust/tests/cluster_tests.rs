//! The threaded leader/worker cluster must be trace-identical to the
//! central fast-path simulation (same seed ⇒ same messages ⇒ same model).

use lad::aggregation::Cwtm;
use lad::attack::{NoAttack, SignFlip};
use lad::compress::{Identity, RandK};
use lad::config::TrainConfig;
use lad::data::linreg::LinRegDataset;
use lad::grad::NativeLinReg;
use lad::server::cluster::run_cluster;
use lad::server::trainer::Trainer;
use lad::util::rng::Rng;

fn cfg(n: usize, h: usize, d: usize) -> TrainConfig {
    let mut cfg = TrainConfig::default();
    cfg.n_devices = n;
    cfg.n_honest = h;
    cfg.d = d;
    cfg.dim = 12;
    cfg.iters = 80;
    cfg.lr = 8e-5;
    cfg.sigma_h = 0.3;
    cfg.log_every = 20;
    cfg
}

fn parity(cfg: &TrainConfig, attack_on: bool, seed: u64) {
    let mut rng = Rng::new(seed);
    let ds = LinRegDataset::generate(cfg.n_devices, cfg.dim, cfg.sigma_h, &mut rng);
    let cwtm = Cwtm::new(0.1);
    let flip = SignFlip { coeff: -2.0 };
    let noatk = NoAttack;
    let attack: &dyn lad::attack::Attack = if attack_on { &flip } else { &noatk };

    let mut x_cluster = vec![0.0f32; cfg.dim];
    let tc = run_cluster(
        cfg, &ds, &cwtm, attack, &Identity, &mut x_cluster, "cluster", &mut Rng::new(seed + 1),
    )
    .unwrap();
    let mut oracle = NativeLinReg::new(ds);
    let mut x_central = vec![0.0f32; cfg.dim];
    let tt = Trainer::new(cfg, &cwtm, attack, &Identity)
        .run(&mut oracle, &mut x_central, "central", &mut Rng::new(seed + 1))
        .unwrap();
    // identical rng consumption => identical trajectories (f32-exact)
    assert_eq!(x_cluster, x_central, "model divergence");
    assert_eq!(tc.loss, tt.loss, "trace divergence");
}

#[test]
fn cluster_matches_central_no_attack() {
    parity(&cfg(10, 8, 3), false, 201);
}

#[test]
fn cluster_matches_central_with_attack() {
    parity(&cfg(12, 9, 4), true, 301);
}

#[test]
fn cluster_matches_central_d1_baseline() {
    parity(&cfg(9, 7, 1), true, 401);
}

#[test]
fn cluster_with_compression_trains() {
    let cfg = cfg(10, 8, 3);
    let mut rng = Rng::new(501);
    let ds = LinRegDataset::generate(cfg.n_devices, cfg.dim, cfg.sigma_h, &mut rng);
    let mut x0 = vec![0.0f32; cfg.dim];
    let l0 = ds.loss(&x0);
    let cwtm = Cwtm::new(0.1);
    let tr = run_cluster(
        &cfg,
        &ds,
        &cwtm,
        &SignFlip { coeff: -2.0 },
        &RandK::new(4),
        &mut x0,
        "cluster-com",
        &mut Rng::new(502),
    )
    .unwrap();
    assert!(tr.final_loss < l0, "{} !< {l0}", tr.final_loss);
    assert!(tr.total_bits() > 0);
}
